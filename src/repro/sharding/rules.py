"""Sharding rules: parameter / activation / cache PartitionSpecs.

Policy (DESIGN.md §5)
---------------------
* batch dims shard over the composed data axes — ``("pod", "data")`` on the
  multi-pod mesh, ``("data",)`` on a single pod.
* weight matrices shard their "wide" dim over ``model``, chosen as the
  FIRST divisible dim from a per-tensor preference list (heads before
  hidden, experts before ffn).  Anything not divisible is replicated —
  correct (XLA SPMD inserts the collectives) and auditable in §Roofline.
* optionally ``fsdp=True`` additionally shards the largest remaining dim
  over the data axes (ZeRO-3 style) — used by the memory-tight configs.
* decode caches shard batch over data when divisible, otherwise the
  sequence-slot dim (long_500k has B=1); KV-heads then head_dim over
  ``model``.

Rules are keyed on (leaf name, rank): every parameter tensor in this
framework has a unique trailing name; stacked (scanned) variants carry
extra leading layer dims, detected as rank - base_rank.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Dict, Optional, Sequence, Set, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger("repro.sharding")


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


# (base_rank, preference list of (dim, purpose)) per trailing param name.
# dims are indices into the UNSTACKED shape; negative ok.
_PARAM_RULES: Dict[str, Tuple[int, Sequence[int]]] = {
    "tok": (2, [0]),                 # (V, D): shard vocab
    "head": (2, [1]),                # (D, V): shard vocab
    "frontend_proj": (2, [1]),
    "wq": (3, [1, 0]),               # (D, H, hd): heads, else D
    "wk": (3, [1, 2, 0]),            # (D, KV, hd): kv, hd, D
    "wv": (3, [1, 2, 0]),
    "wo": (3, [0, 1]),               # (H, hd, D): heads, hd
    "w_gate": (2, [1, 0]),           # dense (D, F)
    "w_up": (2, [1, 0]),
    "w_down": (2, [0, 1]),           # dense (F, D)
    "w_in": (2, [1, 0]),
    "w_out": (2, [0, 1]),
    "in_proj": (2, [1, 0]),          # ssm (D, P)
    "out_proj": (2, [0, 1]),
    "conv_w": (2, [1]),              # (K, C)
}
_MOE_RULES: Dict[str, Tuple[int, Sequence[int]]] = {
    "w_gate": (3, [0, 2]),           # (E, D, F): experts, else ffn
    "w_up": (3, [0, 2]),
    "w_down": (3, [0, 1]),           # (E, F, D)
}
_REPLICATED = {"scale", "bias", "b_in", "b_out", "router", "dt_bias",
               "a_log", "d_skip", "norm_scale", "conv_b", "enc_pos"}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _in_moe(path) -> bool:
    names = [str(getattr(p, "key", "")) for p in path]
    return "ffn" in names and "shared" not in names


def _spec_for_param(path, leaf, mesh: Mesh, fsdp: bool) -> P:
    name = _leaf_name(path)
    rank = leaf.ndim
    model_size = mesh.shape["model"]
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)

    if name in _REPLICATED:
        return P()
    rules = _PARAM_RULES.get(name)
    if name in _MOE_RULES and _in_moe(path):
        base_rank, prefs = _MOE_RULES[name]
        if rank >= base_rank:
            rules = (base_rank, prefs)
    if rules is None:
        return P()
    base_rank, prefs = rules
    offset = rank - base_rank            # leading stacked layer dims
    if offset < 0:
        return P()
    spec = [None] * rank
    model_dim = None
    for d in prefs:
        dim = d + offset
        if leaf.shape[dim] % model_size == 0 and leaf.shape[dim] >= model_size:
            spec[dim] = "model"
            model_dim = dim
            break
    if fsdp and dsize > 1:
        # ZeRO-3: shard the largest remaining dim over the data axes
        cands = [i for i in range(offset, rank)
                 if i != model_dim and leaf.shape[i] % dsize == 0
                 and leaf.shape[i] >= dsize]
        if cands:
            biggest = max(cands, key=lambda i: leaf.shape[i])
            spec[biggest] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def param_shardings(shapes: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """shapes: pytree of ShapeDtypeStructs (or arrays).  Returns a matching
    pytree of NamedSharding."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    out = [NamedSharding(mesh, _spec_for_param(p, l, mesh, fsdp))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Batch (activation inputs)
# ---------------------------------------------------------------------------


def batch_shardings(specs: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    dspec = daxes if len(daxes) > 1 else daxes[0]
    out = {}
    for k, v in specs.items():
        B = v.shape[0]
        if B % dsize == 0 and B >= dsize:
            out[k] = NamedSharding(mesh, P(dspec, *([None] * (v.ndim - 1))))
        else:
            out[k] = NamedSharding(mesh, P())
    return out


# ---------------------------------------------------------------------------
# Serve caches
# ---------------------------------------------------------------------------

# dense logical KV fields, laid out (..., B, S, KV, head_dim)
_KV_FIELD_NAMES = ("k", "v", "dense_k", "dense_v", "cross_k", "cross_v",
                   "ctx_k", "ctx_v", "gen_k", "gen_v", "hist_k", "hist_v")

# (batch, dsize) pairs already warned about — the replication fallback
# silently costs a data-parallel factor, so it is logged ONCE per shape
# (tests reset this set to re-arm the warning)
_WARNED_BATCH_FALLBACK: Set[Tuple[int, int]] = set()


def _batch_divisible(batch: int, mesh: Mesh, *, warn: bool = True) -> bool:
    """True when the cache batch/slot dim can shard over the data axes.
    When it cannot (and the mesh actually has data parallelism), warn
    once per (batch, data-size): the fallback is replication, which is
    correct but silently forfeits a ``dsize``x memory/compute split."""
    dsize = _axis_size(mesh, data_axes(mesh))
    ok = batch % dsize == 0 and batch >= dsize
    if not ok and dsize > 1 and warn:
        key = (batch, dsize)
        if key not in _WARNED_BATCH_FALLBACK:
            _WARNED_BATCH_FALLBACK.add(key)
            logger.warning(
                "cache batch/slot dim %d is not divisible by the data-axis "
                "size %d; falling back to replication over the data axes "
                "(seq-dim sharding only where divisible) — pick slots as a "
                "multiple of the data axes to regain the split",
                batch, dsize)
    return ok


def _cache_spec(path, leaf, mesh: Mesh, batch: int) -> P:
    name = _leaf_name(path)
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    msize = mesh.shape["model"]
    dspec = daxes if len(daxes) > 1 else daxes[0]
    shape = leaf.shape
    rank = leaf.ndim
    spec: list = [None] * rank
    b_ok = _batch_divisible(batch, mesh)

    # locate the batch dim: the first dim equal to `batch`
    b_dim = next((i for i, s in enumerate(shape) if s == batch), None)

    if name in ("len", "hist_len", "gen_len"):
        return P()
    if name == "tokens":
        if b_ok:
            spec[0] = dspec
        elif shape[1] % dsize == 0:
            spec[1] = dspec               # shard the id buffer over seq
        return P(*spec)
    if name in ("ctx_valid",):
        if b_ok and b_dim is not None:
            spec[b_dim] = dspec
        return P(*spec)
    if name in _KV_FIELD_NAMES:
        # layout (..., B, S, KV, hd)
        s_dim, kv_dim, hd_dim = rank - 3, rank - 2, rank - 1
        b_dim = rank - 4
        if b_ok:
            spec[b_dim] = dspec
        elif shape[s_dim] % dsize == 0 and shape[s_dim] >= dsize:
            spec[s_dim] = dspec           # long_500k: shard cache over seq
        if shape[kv_dim] % msize == 0 and shape[kv_dim] >= msize:
            spec[kv_dim] = "model"
        elif shape[hd_dim] % msize == 0 and shape[hd_dim] >= msize:
            spec[hd_dim] = "model"
        return P(*spec)
    if name == "ssm":
        # (L, B, H, P, N)
        if b_ok:
            spec[1] = dspec
        if shape[2] % msize == 0 and shape[2] >= msize:
            spec[2] = "model"
        elif shape[3] % msize == 0 and shape[3] >= msize:
            spec[3] = "model"
        return P(*spec)
    if name == "conv":
        # (L, B, K-1, C)
        if b_ok:
            spec[1] = dspec
        if shape[3] % msize == 0 and shape[3] >= msize:
            spec[3] = "model"
        return P(*spec)
    return P()


def cache_shardings(cache_shapes: Any, mesh: Mesh, batch: int) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    out = [NamedSharding(mesh, _cache_spec(p, l, mesh, batch))
           for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def generic_sharding(leaf, mesh: Mesh, fsdp: bool = False) -> NamedSharding:
    """Shard the largest model-divisible dim over `model` (+ next largest
    over data when fsdp) — used for tensors without a named rule, e.g.
    factored optimizer statistics."""
    spec: list = [None] * leaf.ndim
    msize = mesh.shape["model"]
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    dims = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
    for d in dims:
        if spec[d] is None and leaf.shape[d] % msize == 0 \
                and leaf.shape[d] >= msize:
            spec[d] = "model"
            break
    if fsdp and dsize > 1:
        for d in dims:
            if spec[d] is None and leaf.shape[d] % dsize == 0 \
                    and leaf.shape[d] >= dsize:
                spec[d] = daxes if len(daxes) > 1 else daxes[0]
                break
    return NamedSharding(mesh, P(*spec))


def opt_shardings(param_sh: Any, opt_shapes: Any, mesh: Mesh,
                  fsdp: bool = False) -> Any:
    """Optimizer m inherits the parameter shardings; v matches when
    unfactored, else row/col statistics get generic shardings; step is
    replicated."""
    from repro.training.optim import OptState
    v_sh = jax.tree_util.tree_map(
        lambda l: generic_sharding(l, mesh, fsdp), opt_shapes.v)
    return OptState(step=NamedSharding(mesh, P()),
                    m=param_sh, v=v_sh)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Decode-state sharding (mesh-native serving)
#
# Per-field policy for BOTH DecodeState partitions (kv + bookkeeping):
#
# * dense / int8 KV buffers (..., B, S, KV, hd): slot dim over the data
#   axes (when divisible — the warn-once fallback above applies), KV-head
#   dim over ``model``; int8 ``__scale`` pools ride their parent ``__q``
#   spec with the trailing size-1 dim always replicated.
# * paged pools (..., pool_pages+1, page, KV, hd): KV-head dim over
#   ``model``; the page axis is REPLICATED over data — any slot may own
#   any page under the host-side allocator (prefix sharing, CoW forks),
#   so a data-sharded pool would need a shard-local allocator (the
#   disaggregated-serving follow-up, see docs/sharding.md).  Per-device
#   KV bytes are therefore global / model_shards.
# * page tables and all ``layout__*`` bookkeeping: replicated (tiny
#   int32 — every shard walks the same table).
# * plain bookkeeping (tokens, lengths, done, phase counters): slot dim
#   over data when divisible, else replicated.
# ---------------------------------------------------------------------------

_LAYOUT_BK_PREFIX = "layout__"          # mirrors repro.models.layouts


def decode_field_spec(name: str, shape: Tuple[int, ...], mesh: Mesh, *,
                      batch: int, baxis: Optional[int] = None,
                      pool_axis: Optional[int] = None) -> P:
    """PartitionSpec for one physical DecodeState field.

    ``baxis`` is the field's batch ("slot") axis (None for fields with
    no slot dim, e.g. shared paged pools); ``pool_axis`` is the pool
    page axis for paged fields (None otherwise).  Pure shape/name
    computation — usable with any object exposing ``.shape`` /
    ``.axis_names`` (tests use a fake mesh)."""
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    dspec = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    rank = len(shape)
    spec: list = [None] * rank

    if name.startswith(_LAYOUT_BK_PREFIX):
        return P()                       # page tables et al: replicated
    is_scale = name.endswith("__scale")
    base = name[:-len("__scale")] if is_scale else \
        (name[:-len("__q")] if name.endswith("__q") else name)

    def _model_dim(*dims: int) -> None:
        for d in dims:
            if is_scale and shape[d] == 1:
                continue                 # scale's trailing 1: replicated
            if msize > 1 and shape[d] % msize == 0 and shape[d] >= msize:
                spec[d] = "model"
                return

    if pool_axis is not None:
        # shared paged pool: (..., pool_pages+1, page, KV, hd) — KV-head
        # dim only: a head-dim split would change the QK/AV contraction
        # order (MQA pools replicate over model instead)
        _model_dim(rank - 2)
        return P(*spec)
    if baxis is not None and dspec is not None \
            and _batch_divisible(batch, mesh):
        spec[baxis] = dspec
    if base in _KV_FIELD_NAMES:
        # KV-head dim ONLY: splitting head_dim instead would split the
        # QK/AV contractions (collectives + a different f32 reduction
        # order — greedy streams could flip).  MQA (KV=1) replicates
        # over model; the data axis still splits slots.
        _model_dim(rank - 2)
    elif base == "ssm" and baxis is not None and rank - baxis >= 3:
        _model_dim(baxis + 1, baxis + 2)  # (.., B, H, P, N): heads, state
    elif base == "conv" and baxis is not None and rank >= 2:
        _model_dim(rank - 1)             # (.., B, K-1, C): channels
    return P(*spec)


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Hashable decode-mesh handle carried in DecodeState pytree aux data
    and on the (frozen) DecodeAPI dataclasses.

    Holds only the mesh: per-field specs are a pure function of (name,
    shape, mesh) via :func:`decode_field_spec`, so the context never goes
    stale when slots / max_len / layout change."""

    mesh: Mesh

    @property
    def data_shards(self) -> int:
        return _axis_size(self.mesh, data_axes(self.mesh))

    @property
    def model_shards(self) -> int:
        return self.mesh.shape["model"] \
            if "model" in self.mesh.axis_names else 1

    def spec(self, name: str, shape, *, batch: int,
             baxis: Optional[int] = None,
             pool_axis: Optional[int] = None) -> P:
        return decode_field_spec(name, tuple(shape), self.mesh, batch=batch,
                                 baxis=baxis, pool_axis=pool_axis)

    def sharding(self, name: str, shape, *, batch: int,
                 baxis: Optional[int] = None,
                 pool_axis: Optional[int] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(
            name, shape, batch=batch, baxis=baxis, pool_axis=pool_axis))

    def apply(self, x, sharding: NamedSharding):
        """Pin ``x`` to ``sharding``: a sharding constraint under
        tracing (state surgery inside jit preserves shardings instead of
        silently gathering), ``jax.device_put`` on concrete arrays
        (initial placement)."""
        if isinstance(x, jax.core.Tracer):
            return jax.lax.with_sharding_constraint(x, sharding)
        return jax.device_put(x, sharding)


def as_mesh_context(mesh) -> Optional[MeshContext]:
    """Normalise None | Mesh | MeshContext to Optional[MeshContext]."""
    if mesh is None or isinstance(mesh, MeshContext):
        return mesh
    return MeshContext(mesh)


def decode_shardings(cfg, mesh: Mesh, layout: Any = None, *,
                     slots: int, max_len: int):
    """Per-field NamedShardings for both DecodeState partitions of
    ``build_decode(cfg, layout)`` at (slots, max_len) — a DecodeState-
    structured pytree of NamedSharding (usable directly as jit
    in/out_shardings).  No device allocation (eval_shape)."""
    from repro.models.api import build_decode     # circular-free at call
    decode = build_decode(cfg, layout)
    state = jax.eval_shape(lambda: decode.init_state(slots, max_len))
    return state.field_shardings(MeshContext(mesh))


# ---------------------------------------------------------------------------
# Activation sharding context (MaxText-style logical constraints)
#
# GSPMD's propagation drops the batch sharding of the residual stream when
# the FSDP-sharded embedding gather creates a data-axis conflict (measured:
# a 16x activation blowup on llama3-405b — EXPERIMENTS.md §Perf).  The
# launchers opt in to explicit constraints; tests/examples (1 device) leave
# this unset and every call is a no-op.
# ---------------------------------------------------------------------------

_ACT: Dict[str, Any] = {"mesh": None, "seq_parallel": False}


def set_activation_context(mesh: Optional[Mesh],
                           seq_parallel: bool = False) -> None:
    _ACT["mesh"] = mesh
    _ACT["seq_parallel"] = seq_parallel


def shard_act(x, batch_ok: bool = True):
    """Constrain an activation (batch, seq, ...) to batch-over-data; when
    the batch cannot shard (e.g. B=1 long-context) fall back to
    seq-over-data [+ seq-over-model when seq_parallel].  No-op without
    context."""
    mesh = _ACT["mesh"]
    if mesh is None:
        return x
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    dspec = daxes if len(daxes) > 1 else daxes[0]
    spec = [None] * x.ndim
    if batch_ok and x.shape[0] % dsize == 0 and x.shape[0] >= dsize:
        spec[0] = dspec
    elif x.ndim >= 3 and x.shape[1] % dsize == 0 and x.shape[1] >= dsize:
        spec[1] = dspec               # B=1 long-context: shard the sequence
    if _ACT["seq_parallel"] and x.ndim >= 3 and spec[1] is None:
        msize = mesh.shape["model"]
        if x.shape[1] % msize == 0 and x.shape[1] >= msize:
            spec[1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))

"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191] splits the head dim into (temporal, height, width)
sections, each rotated by its own position stream.  For the language-only
backbone built here the three streams coincide for text tokens and diverge
for (stubbed) vision tokens, so the implementation takes a ``(3, B, L)``
position tensor; plain text passes the same positions three times.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions.

    positions: (..., L) int32 -> cos/sin of shape (..., L, head_dim // 2).
    """
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate x of shape (B, L, H, D) with cos/sin of shape (B, L, D//2)."""
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]            # broadcast over heads
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(orig_dtype)


def mrope_cos_sin(positions3: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE cos/sin. positions3: (3, ..., L); sections sum to
    head_dim//2.  Returns cos/sin of shape (..., L, head_dim//2)."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)                       # (D/2,)
    ang = positions3.astype(jnp.float32)[..., None] * inv   # (3, ..., L, D/2)
    # Select which of the 3 position streams drives each frequency band.
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections),
                     total_repeat_length=head_dim // 2)     # (D/2,)
    a = jnp.moveaxis(ang, 0, -1)                            # (..., D/2, 3)
    idx = sel.reshape((1,) * (a.ndim - 2) + (head_dim // 2, 1))
    idx = jnp.broadcast_to(idx, a.shape[:-1] + (1,))
    ang = jnp.take_along_axis(a, idx, axis=-1)[..., 0]      # (..., L, D/2)
    return jnp.cos(ang), jnp.sin(ang)


def text_positions3(positions: jax.Array) -> jax.Array:
    """Text-only M-RoPE positions: all three streams equal."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)

"""Shared building blocks: parameter init helpers and normalisation layers.

The framework uses a functional, explicit-parameter style: every layer is an
``init_*(key, cfg, ...) -> params`` plus an ``apply(params, x, ...) -> y``
pair, with params as plain nested dicts of ``jnp.ndarray``.  This keeps the
whole model a transparent pytree for ``jax.jit`` sharding.
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(name: str) -> jnp.dtype:
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Sequence[int], dtype: str = "float32",
               fan_in: int | None = None) -> jax.Array:
    """Truncated-normal init scaled by 1/sqrt(fan_in) (LeCun normal)."""
    if fan_in is None:
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    std = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key: jax.Array, shape: Sequence[int],
               dtype: str = "float32") -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(shape: Sequence[int], dtype: str = "float32") -> jax.Array:
    return jnp.zeros(shape, dtype=dtype)


def ones_init(shape: Sequence[int], dtype: str = "float32") -> jax.Array:
    return jnp.ones(shape, dtype=dtype)


# ---------------------------------------------------------------------------
# RMSNorm (llama-family default everywhere; whisper uses LayerNorm)
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype: str = "float32") -> Params:
    return {"scale": ones_init((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig_dtype)


def init_layernorm(d: int, dtype: str = "float32") -> Params:
    return {"scale": ones_init((d,), dtype), "bias": zeros_init((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(orig_dtype)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def where_rows(rows: jax.Array, new: jax.Array, old: jax.Array,
               axis: int) -> jax.Array:
    """Per-row select along a batch axis: take ``new`` where ``rows``
    (B,) is True, else ``old``.  Shared by the TConst row-selective
    resync and the serving layer's DecodeState slot freezing."""
    shape = [1] * new.ndim
    shape[axis] = rows.shape[0]
    return jnp.where(rows.reshape(shape), new, old)


def take_rows(arr: jax.Array, idx: jax.Array, axis: int) -> jax.Array:
    """Gather rows ``idx`` along a batch axis (one dispatch, any count).
    Shared by the compacted resync and the cache-layout row scatter."""
    return jnp.take(arr, idx, axis=axis)


def put_rows(arr: jax.Array, idx: jax.Array, vals: jax.Array,
             axis: int) -> jax.Array:
    """Scatter rows ``vals`` back into ``idx`` along a batch axis."""
    moved = jnp.moveaxis(arr, axis, 0)
    moved = moved.at[idx].set(jnp.moveaxis(vals, axis, 0).astype(arr.dtype))
    return jnp.moveaxis(moved, 0, axis)

"""Feed-forward networks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import Params, dense_init, zeros_init, split_keys


def init_swiglu(key: jax.Array, d_model: int, d_ff: int,
                param_dtype: str = "float32") -> Params:
    kg, ku, kd = split_keys(key, 3)
    return {
        "w_gate": dense_init(kg, (d_model, d_ff), param_dtype, fan_in=d_model),
        "w_up": dense_init(ku, (d_model, d_ff), param_dtype, fan_in=d_model),
        "w_down": dense_init(kd, (d_ff, d_model), param_dtype, fan_in=d_ff),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    g = jnp.einsum("bld,df->blf", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("bld,df->blf", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    return jnp.einsum("blf,fd->bld", h, params["w_down"].astype(dtype))


def init_gelu_mlp(key: jax.Array, d_model: int, d_ff: int,
                  param_dtype: str = "float32") -> Params:
    k1, k2 = split_keys(key, 2)
    return {
        "w_in": dense_init(k1, (d_model, d_ff), param_dtype, fan_in=d_model),
        "b_in": zeros_init((d_ff,), param_dtype),
        "w_out": dense_init(k2, (d_ff, d_model), param_dtype, fan_in=d_ff),
        "b_out": zeros_init((d_model,), param_dtype),
    }


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    h = jnp.einsum("bld,df->blf", x, params["w_in"].astype(dtype))
    h = h + params["b_in"].astype(dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    y = jnp.einsum("blf,fd->bld", h, params["w_out"].astype(dtype))
    return y + params["b_out"].astype(dtype)

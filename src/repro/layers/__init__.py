from repro.layers import attention, common, embed, mlp, moe, rope, ssm  # noqa: F401

"""Token embeddings, output head, and modality frontend stubs.

Per the assignment carve-out, the audio/vision frontends are stubs: the
model consumes precomputed frame/patch embeddings supplied via
``input_specs()``.  ``frontend_proj`` is the (real, trained) projector that
maps frontend embeddings into the backbone width.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.common import Params, dense_init, embed_init, split_keys


def init_embed(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, ko, kf = split_keys(key, 3)
    params: Params = {
        "tok": embed_init(ke, (cfg.vocab_size, cfg.d_model), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(
            ko, (cfg.d_model, cfg.vocab_size), cfg.param_dtype,
            fan_in=cfg.d_model)
    if cfg.frontend != "none":
        fdim = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = dense_init(
            kf, (fdim, cfg.d_model), cfg.param_dtype, fan_in=fdim)
    return params


def embed_tokens(params: Params, tokens: jax.Array,
                 dtype: jnp.dtype) -> jax.Array:
    return params["tok"].astype(dtype)[tokens]


def project_frontend(params: Params, feats: jax.Array) -> jax.Array:
    """Map stub frontend embeddings (B, T, frontend_dim) into d_model."""
    return jnp.einsum("btf,fd->btd", feats,
                      params["frontend_proj"].astype(feats.dtype))


def lm_head(params: Params, x: jax.Array,
             logit_softcap: float = 0.0) -> jax.Array:
    if "head" in params:
        logits = jnp.einsum("bld,dv->blv", x, params["head"].astype(x.dtype))
    else:
        logits = jnp.einsum("bld,vd->blv", x, params["tok"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if logit_softcap > 0.0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    return logits

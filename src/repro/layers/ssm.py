"""Mamba-2 SSD (state-space duality) layer [arXiv:2405.21060].

Implements the chunked SSD algorithm: within a chunk the recurrence is
computed as masked (decay-weighted) matmuls — MXU-friendly — and across
chunks a ``jax.lax.scan`` carries the (H, P, N) state.  This pure-jnp
implementation is the oracle; ``repro.kernels.ssd_scan`` provides the
Pallas intra-chunk kernel.

Layer layout follows the mamba2 block: in_proj -> (z, x, B, C, dt),
depthwise causal conv over (x, B, C), SSD core, gated norm, out_proj.
Single B/C group (n_groups=1), scalar A per head.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.common import Params, dense_init, split_keys


class SSMDims(NamedTuple):
    d_inner: int
    n_heads: int
    head_dim: int
    n_state: int
    d_conv: int
    conv_dim: int


def ssm_dims(cfg: ModelConfig, d_model: Optional[int] = None) -> SSMDims:
    d = d_model or cfg.d_model
    d_inner = cfg.ssm_expand * d
    head_dim = cfg.ssm_head_dim or 64
    n_heads = cfg.ssm_heads or d_inner // head_dim
    n_state = cfg.ssm_state
    conv_dim = d_inner + 2 * n_state
    return SSMDims(d_inner, n_heads, head_dim, n_state, cfg.ssm_conv, conv_dim)


def init_ssm(key: jax.Array, cfg: ModelConfig,
             d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dims = ssm_dims(cfg, d)
    kin, kconv, kdt, ka, kout, knorm = split_keys(key, 6)
    d_proj = 2 * dims.d_inner + 2 * dims.n_state + dims.n_heads
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(kdt, (dims.n_heads,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1)))))
    return {
        "in_proj": dense_init(kin, (d, d_proj), cfg.param_dtype, fan_in=d),
        "conv_w": dense_init(kconv, (dims.d_conv, dims.conv_dim),
                             cfg.param_dtype, fan_in=dims.d_conv),
        "conv_b": jnp.zeros((dims.conv_dim,), cfg.param_dtype),
        "dt_bias": dt_bias.astype(cfg.param_dtype),
        "a_log": jnp.log(jnp.arange(1, dims.n_heads + 1, dtype=jnp.float32)
                         ).astype(cfg.param_dtype),
        "d_skip": jnp.ones((dims.n_heads,), cfg.param_dtype),
        "norm_scale": jnp.ones((dims.d_inner,), cfg.param_dtype),
        "out_proj": dense_init(kout, (dims.d_inner, d), cfg.param_dtype,
                               fan_in=dims.d_inner),
    }


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k].

    x: (..., Q) -> (..., Q, Q) lower-triangular cumulative log-decays.
    """
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (Bt, L, H, P)   inputs (already multiplied by nothing; dt applied here)
    dt: (Bt, L, H)     positive step sizes
    a: (H,)            negative decay rates (A = -exp(a_log))
    b, c: (Bt, L, N)   input/output projections (single group, broadcast to H)
    Returns (y (Bt, L, H, P), final_state (Bt, H, P, N)).
    """
    Bt, L, H, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    f32 = jnp.float32

    xc = x.astype(f32).reshape(Bt, nc, chunk, H, P)
    dtc = dt.astype(f32).reshape(Bt, nc, chunk, H)
    bc = b.astype(f32).reshape(Bt, nc, chunk, N)
    cc = c.astype(f32).reshape(Bt, nc, chunk, N)

    da = dtc * a.astype(f32)[None, None, None, :]          # (Bt, nc, Q, H) log-decay
    da = jnp.moveaxis(da, -1, 2)                           # (Bt, nc, H, Q)
    seg = _segsum(da)                                      # (Bt, nc, H, Q, Q)
    decay_mat = jnp.exp(seg)

    # intra-chunk (diagonal blocks): y_intra[l] = sum_{s<=l} C_l.B_s decay x_s dt_s
    xdt = xc * dtc[..., None]                              # (Bt,nc,Q,H,P)
    scores = jnp.einsum("bnlm,bnsm->bnls", cc, bc)         # (Bt,nc,Q,Q)
    y_intra = jnp.einsum("bnls,bnhls,bnshp->bnlhp",
                         scores, decay_mat, xdt)

    # chunk-final states: state_n = sum_s decay_to_end * B_s xdt_s
    decay_to_end = jnp.exp(jnp.cumsum(da[..., ::-1], axis=-1)[..., ::-1] - da)
    # decay from step s (exclusive) to end of chunk: (Bt,nc,H,Q)
    states = jnp.einsum("bnsm,bnhs,bnshp->bnhpm", bc, decay_to_end, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(da, axis=-1))            # (Bt, nc, H)
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((Bt, H, P, N), f32))

    # scan emits the state BEFORE each chunk; carry ends as the final state
    final, prev_states = jax.lax.scan(
        lambda c, i: ((c * i[1][:, :, None, None] + i[0]), c),
        s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (Bt,nc,H,P,N)

    # contribution of carried state into each chunk
    decay_from_start = jnp.exp(jnp.cumsum(da, axis=-1))    # (Bt,nc,H,Q)
    y_inter = jnp.einsum("bnlm,bnhl,bnhpm->bnlhp",
                         cc, decay_from_start, prev_states)

    y = (y_intra + y_inter).reshape(Bt, L, H, P)
    return y.astype(x.dtype), final


def ssd_step(state: jax.Array, x: jax.Array, dt: jax.Array, a: jax.Array,
             b: jax.Array, c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent step (decode path) — O(1) in sequence length.

    state: (Bt, H, P, N); x: (Bt, H, P); dt: (Bt, H); b, c: (Bt, N).
    """
    f32 = jnp.float32
    dec = jnp.exp(dt.astype(f32) * a.astype(f32)[None])    # (Bt, H)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]        # (Bt, H, P)
    new = state.astype(f32) * dec[:, :, None, None] + \
        jnp.einsum("bhp,bm->bhpm", xdt, b.astype(f32))
    y = jnp.einsum("bhpm,bm->bhp", new, c.astype(f32))
    return y.astype(x.dtype), new


# ---------------------------------------------------------------------------
# Full mamba2 mixer (projections + conv + SSD + gate)
# ---------------------------------------------------------------------------


def _split_proj(z_all: jax.Array, dims: SSMDims):
    di, n = dims.d_inner, dims.n_state
    z = z_all[..., :di]
    xbc = z_all[..., di:di + dims.conv_dim]
    dt = z_all[..., di + dims.conv_dim:]
    return z, xbc, dt


def causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                prev: Optional[jax.Array] = None,
                valid_len: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xbc: (B, L, C); w: (K, C).

    prev: (B, K-1, C) trailing context from the previous segment (decode).
    valid_len: optional (B,) — only positions ``[0, valid_len)`` are real
    (chunked prefill pads the last chunk): the returned context window
    then ends at ``valid_len`` instead of L, so trailing padding never
    enters the next segment's conv state.
    Returns (out (B, L, C), new_prev (B, K-1, C)).
    """
    K = w.shape[0]
    B, L, C = xbc.shape
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)              # (B, L+K-1, C)
    out = jnp.zeros((B, L, C), jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + L].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    out = jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)
    if valid_len is None:
        return out, xp[:, L:]
    # window of the K-1 inputs preceding position valid_len: xp index j
    # holds segment position j - (K-1), so the window is xp[vl : vl+K-1]
    idx = valid_len[:, None] + jnp.arange(K - 1)[None]     # (B, K-1)
    return out, jnp.take_along_axis(xp, idx[..., None], axis=1)


def ssm_mixer(params: Params, x: jax.Array, cfg: ModelConfig,
              d_model: Optional[int] = None,
              state: Optional[dict] = None,
              valid_len: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[dict]]:
    """Mamba2 mixer. x: (B, L, d). If ``state`` is given (keys: ssm, conv),
    runs in stepwise/streaming mode and returns the updated state.

    valid_len: optional (B,) — positions ``>= valid_len`` are padding
    (the chunked prefill's trailing pad): their ``dt`` is forced to 0,
    which makes the SSD update an exact identity (``exp(0·a) = 1`` decay,
    zero input contribution), and the conv context window ends at
    ``valid_len`` — so the returned state is the state after the REAL
    tokens, bit-for-bit.  Padding rows' outputs are garbage (discarded
    by the caller)."""
    from repro.sharding.rules import shard_act
    dims = ssm_dims(cfg, d_model)
    dtype = x.dtype
    B, L, d = x.shape
    z_all = shard_act(jnp.einsum("bld,dp->blp", x,
                                 params["in_proj"].astype(dtype)))
    z, xbc, dt_raw = _split_proj(z_all, dims)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (B, L, H)
    if valid_len is not None:
        dt = jnp.where(jnp.arange(L)[None, :, None] < valid_len[:, None,
                                                                None],
                       dt, 0.0)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    prev_conv = state["conv"] if state is not None else None
    xbc, new_conv = causal_conv(xbc, params["conv_w"], params["conv_b"],
                                prev_conv, valid_len=valid_len)
    xs = xbc[..., :dims.d_inner].reshape(B, L, dims.n_heads, dims.head_dim)
    b = xbc[..., dims.d_inner:dims.d_inner + dims.n_state]
    c = xbc[..., dims.d_inner + dims.n_state:]

    if state is not None and L == 1:
        y, new_ssm = ssd_step(state["ssm"], xs[:, 0], dt[:, 0], a,
                              b[:, 0], c[:, 0])
        y = y[:, None]
    else:
        init = state["ssm"] if state is not None else None
        chunk = min(cfg.ssm_chunk, L)
        while L % chunk != 0:
            chunk //= 2
        y, new_ssm = ssd_chunked(xs, dt, a, b, c, max(1, chunk), init)

    y = y + xs * params["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(B, L, dims.d_inner)

    # gated RMSNorm (mamba2 uses norm(y * silu(z)))
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(jnp.float32)
    out = jnp.einsum("blp,pd->bld", g.astype(dtype),
                     params["out_proj"].astype(dtype))
    new_state = {"ssm": new_ssm, "conv": new_conv} if state is not None \
        else None
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int,
                   d_model: Optional[int] = None) -> dict:
    dims = ssm_dims(cfg, d_model)
    return {
        "ssm": jnp.zeros((batch, dims.n_heads, dims.head_dim, dims.n_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, dims.d_conv - 1, dims.conv_dim),
                          dtype_from(cfg)),
    }


def dtype_from(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)

"""Mixture-of-Experts FFN with GShard-style top-k capacity routing.

Covers both assigned MoE flavours:

- mixtral-8x22b [arXiv:2401.04088]: 8 experts, top-2, no shared experts.
- deepseek-moe-16b [arXiv:2401.06066]: fine-grained experts (small
  ``moe_d_ff``), 64 routed top-6 PLUS 2 always-on shared experts whose
  output is added unconditionally.

Routing uses dispatch/combine one-hot tensors with a capacity factor so the
per-expert compute is static-shaped (XLA/TPU requirement) and the expert
dimension can be sharded over the ``model`` mesh axis (expert parallelism);
XLA then lowers the dispatch einsums to all-to-all style collectives, which
the roofline pass audits.  Tokens overflowing an expert's capacity are
dropped for that expert (standard GShard behaviour); the auxiliary
load-balance loss keeps the router near-uniform so drops stay rare.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.common import Params, dense_init, split_keys

CAPACITY_FACTOR = 1.25


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    d, e = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    kr, kg, ku, kd, ks = split_keys(key, 5)
    params: Params = {
        "router": dense_init(kr, (d, e), cfg.param_dtype, fan_in=d),
        "w_gate": dense_init(kg, (e, d, ff), cfg.param_dtype, fan_in=d),
        "w_up": dense_init(ku, (e, d, ff), cfg.param_dtype, fan_in=d),
        "w_down": dense_init(kd, (e, ff, d), cfg.param_dtype, fan_in=ff),
    }
    if cfg.n_shared_experts > 0:
        from repro.layers.mlp import init_swiglu
        params["shared"] = init_swiglu(
            ks, d, ff * cfg.n_shared_experts, cfg.param_dtype)
    return params


def _capacity(n_tokens: int, n_experts: int, top_k: int) -> int:
    cap = int(n_tokens * top_k * CAPACITY_FACTOR / n_experts)
    return max(4, -(-cap // 4) * 4)  # round up to multiple of 4


def route_topk(logits: jax.Array, top_k: int, capacity: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """GShard dispatch/combine from router logits.

    logits: (T, E). Returns (dispatch (T, E, C) bool-ish float,
    combine (T, E, C) float, aux_loss scalar).
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)             # (T, K)
    # renormalise the top-k gates (mixtral / deepseek convention)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # expert one-hots per choice: (K, T, E)
    onehot = jax.nn.one_hot(gate_idx.T, E, dtype=jnp.float32)
    # position of each (choice, token) within its expert queue: running count
    flat = onehot.reshape(top_k * T, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat                # (K*T, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(top_k, T)
    keep = (pos < capacity).astype(jnp.float32)                    # (K, T)

    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)      # (K, T, C)
    # combine[t, e, c] = sum_k gate * onehot[k,t,e] * pos_oh[k,t,c] * keep
    combine = jnp.einsum("kt,kte,ktc->tec",
                         gate_vals.T * keep, onehot, pos_oh)
    dispatch = (combine > 0).astype(logits.dtype)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(onehot[0], axis=0)                          # top-1 assign
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E
    return dispatch, combine.astype(logits.dtype), aux


GROUP_SIZE = 1024      # GShard routing group: bounds dispatch-tensor memory


def moe_ffn(params: Params, x: jax.Array, cfg: ModelConfig,
            capacity_factor: float | None = None,
            group_size: int | None = None
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (y, aux_loss).

    Routing is GROUP-wise (GShard): tokens are split into groups of
    ``group_size`` and routed with a per-group capacity, so the dispatch/
    combine tensors are (G, Tg, E, C) with Tg*C bounded — O(T) total
    memory instead of the O(T^2/E) of flat routing, and the group dim
    shards over ``data`` while experts shard over ``model`` (the dispatch
    einsums lower to the expert-parallel all-to-all pattern).

    ``capacity_factor=None`` uses the production CAPACITY_FACTOR; tests can
    pass ``n_experts/top_k`` for dropless-exact routing.  Capacity drops
    are standard GShard training semantics; the single-token decode path
    never drops, so train/serve outputs coincide exactly only in the
    dropless limit.
    """
    dtype = x.dtype
    B, L, d = x.shape
    T = B * L
    gs = group_size or min(T, GROUP_SIZE)
    while T % gs != 0:
        gs //= 2
    G = T // gs
    xt = x.reshape(G, gs, d)
    logits = jnp.einsum("gtd,de->gte", xt, params["router"].astype(dtype))
    cf = CAPACITY_FACTOR if capacity_factor is None else capacity_factor
    cap = max(4, -(-int(gs * cfg.n_experts_per_tok * cf
                        / cfg.n_experts) // 4) * 4)

    dispatch, combine, aux = jax.vmap(
        lambda lg: route_topk(lg, cfg.n_experts_per_tok, cap))(logits)

    # dispatch tokens to per-group expert buffers: (G, E, C, d)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xt)
    g = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine, ye).reshape(B, L, d)

    if "shared" in params:
        from repro.layers.mlp import swiglu
        y = y + swiglu(params["shared"], x)
    return y.astype(dtype), jnp.mean(aux)


def moe_ffn_dense_oracle(params: Params, x: jax.Array, cfg: ModelConfig
                         ) -> jax.Array:
    """Dropless reference: every expert computed for every token, combined
    with renormalised top-k gates.  O(E) cost — tests only."""
    dtype = x.dtype
    B, L, d = x.shape
    xt = x.reshape(B * L, d)
    logits = jnp.einsum("td,de->te", xt, params["router"].astype(dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], gate_idx].set(gate_vals)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"].astype(dtype))
    u = jnp.einsum("td,edf->tef", xt, params["w_up"].astype(dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * u
    ye = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(dtype))
    y = jnp.einsum("te,ted->td", gates.astype(dtype), ye).reshape(B, L, d)
    if "shared" in params:
        from repro.layers.mlp import swiglu
        y = y + swiglu(params["shared"], x.reshape(B, L, d))
    return y

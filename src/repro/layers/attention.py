"""Grouped-query attention with causal / sliding / full / cross variants.

This is the reference (pure-jnp) attention used everywhere by default; the
perf-critical paths can be routed through the Pallas kernels in
``repro.kernels`` via ``repro.runtime.flags.use_pallas``.

All attention in the paper is the same softmax(QK^T/sqrt(d))V primitive with
different connection patterns (paper Fig. 2); we expose that as a ``mask``
argument so the TConstFormer core can compose its four patterns (causal
self, full self, compress cross, restore cross) from one implementation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers.common import Params, dense_init, split_keys

NEG_INF = -2.3819763e38  # large negative, safe in bf16/f32


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig,
                   d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = split_keys(key, 4)
    return {
        "wq": dense_init(kq, (d, cfg.n_heads, hd), cfg.param_dtype, fan_in=d),
        "wk": dense_init(kk, (d, cfg.n_kv_heads, hd), cfg.param_dtype, fan_in=d),
        "wv": dense_init(kv, (d, cfg.n_kv_heads, hd), cfg.param_dtype, fan_in=d),
        "wo": dense_init(ko, (cfg.n_heads, hd, d), cfg.param_dtype,
                         fan_in=cfg.n_heads * hd),
    }


def qkv_proj(params: Params, xq: jax.Array, xkv: jax.Array,
             dtype: jnp.dtype) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project queries from xq and keys/values from xkv (same for self-attn)."""
    q = jnp.einsum("bld,dhk->blhk", xq, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dtype))
    return q, k, v


def out_proj(params: Params, o: jax.Array, dtype: jnp.dtype) -> jax.Array:
    return jnp.einsum("blhk,hkd->bld", o, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def make_mask(q_pos: jax.Array, k_pos: jax.Array, mode: str,
              window: "int | jax.Array" = 0) -> Optional[jax.Array]:
    """Boolean (…, Lq, Lk) mask; True = attend.

    mode: "causal" | "sliding" | "full".
    q_pos/k_pos: integer positions, shapes broadcastable to (B, Lq)/(B, Lk)
    or (Lq,)/(Lk,).  ``window`` may be a traced int32 scalar; for mode
    "sliding", window == 0 degrades to plain causal (per-layer patterns).
    """
    if mode == "full":
        return None
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    mask = kp <= qp
    if mode == "sliding":
        w = jnp.asarray(window, jnp.int32)
        weff = jnp.where(w > 0, w, jnp.int32(2**30))
        mask = jnp.logical_and(mask, kp > qp - weff)
    elif mode != "causal":
        raise ValueError(mode)
    return mask


# ---------------------------------------------------------------------------
# Core scaled-dot-product attention (GQA aware)
# ---------------------------------------------------------------------------


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
         mask: Optional[jax.Array] = None,
         logit_softcap: float = 0.0,
         kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: (B, Lq, H, D); k, v: (B, Lk, KV, D); mask: (B?, Lq, Lk) bool.

    kv_valid: optional (B, Lk) bool marking valid cache slots (decode).
    Returns (B, Lq, H, D).
    """
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, Lq, KV, G, D)
    logits = jnp.einsum("blkgd,bskd->bklgs", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))          # (B, KV, Lq, G, Lk)
    if logit_softcap > 0.0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap

    cm = None                                            # (B, Lq, Lk) bool
    if mask is not None:
        cm = mask if mask.ndim == 3 else jnp.broadcast_to(
            mask[None], (B,) + mask.shape)
    if kv_valid is not None:
        kvm = jnp.broadcast_to(kv_valid[:, None, :], (B, Lq, kv_valid.shape[-1]))
        cm = kvm if cm is None else jnp.logical_and(cm, kvm)

    if cm is None:
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        # masked-safe softmax: fully-masked query rows produce zero output
        # (needed by the TConst context path when history is still empty).
        mm = cm[:, None, :, None, :]
        logits = jnp.where(mm, logits, NEG_INF)
        mx = jnp.max(logits, axis=-1, keepdims=True)
        e = jnp.exp(logits - jax.lax.stop_gradient(mx)) * mm
        probs = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)
    o = jnp.einsum("bklgs,bskd->blkgd", probs, v.astype(jnp.float32))
    return o.reshape(B, Lq, H, D).astype(q.dtype)


def attention_block(params: Params, xq: jax.Array, xkv: jax.Array,
                    mask: Optional[jax.Array],
                    cos_q: Optional[jax.Array] = None,
                    sin_q: Optional[jax.Array] = None,
                    cos_k: Optional[jax.Array] = None,
                    sin_k: Optional[jax.Array] = None,
                    logit_softcap: float = 0.0) -> jax.Array:
    """Full projected attention; RoPE applied when cos/sin given."""
    from repro.layers.rope import apply_rope
    dtype = xq.dtype
    q, k, v = qkv_proj(params, xq, xkv, dtype)
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
    if cos_k is not None:
        k = apply_rope(k, cos_k, sin_k)
    o = sdpa(q, k, v, mask, logit_softcap)
    return out_proj(params, o, dtype)


# ---------------------------------------------------------------------------
# Decode-step attention against a static cache
# ---------------------------------------------------------------------------


def cross_attend_cached(params: Params, x: jax.Array, k_cache: jax.Array,
                        v_cache: jax.Array, kv_valid: Optional[jax.Array],
                        cos_q: Optional[jax.Array] = None,
                        sin_q: Optional[jax.Array] = None,
                        logit_softcap: float = 0.0) -> jax.Array:
    """Cross-attention against pre-projected (cached) K/V.

    x: (B, Lq, d); k_cache/v_cache: (B, S, KV, D) already RoPE'd at their
    source positions; kv_valid: (B, S) bool.  Used by the TConst decode path
    (queries attend to the static compressed-context KV).
    """
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(dtype))
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
    o = sdpa(q, k_cache.astype(dtype), v_cache.astype(dtype),
             mask=None, logit_softcap=logit_softcap, kv_valid=kv_valid)
    return out_proj(params, o, dtype)


def project_kv(params: Params, x: jax.Array,
               cos: Optional[jax.Array] = None,
               sin: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Project (and RoPE) K/V for caching. x: (B, S, d) -> (B, S, KV, D)."""
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cos is not None:
        k = apply_rope(k, cos, sin)
    return k, v


def _attend_views(q: jax.Array, k_view, v_view, *,
                  valid_len: Optional[jax.Array] = None,
                  kv_valid: Optional[jax.Array] = None,
                  logit_softcap: float = 0.0,
                  window: "int | jax.Array" = 0) -> jax.Array:
    """Dispatch one-token attention over a per-layer KVView pair
    (``repro.models.layouts``): the kernel consumes the PHYSICAL
    representation.

    * :class:`~repro.models.layouts.PagedView` — in-kernel page-table
      walk (Pallas on the Pallas path, page-at-a-time XLA scan
      otherwise); int8 pools fuse the dequant.  Needs a prefix
      ``valid_len`` (+ optional sliding ``window``).
    * :class:`~repro.models.layouts.QuantView` — fused int8 kernel on
      the Pallas path; dequantise-then-``sdpa`` fallback (XLA fuses the
      scale multiply into the contraction).
    * :class:`~repro.models.layouts.DenseView` — exactly the historic
      dense path (bit-identical to the pre-KVView code).

    q: (B, 1, H, D) RoPE'd queries.  Returns (B, 1, H, D).
    """
    from repro.kernels import ops
    from repro.models import layouts as LT
    dtype = q.dtype
    if isinstance(k_view, LT.PagedView):
        assert kv_valid is None and valid_len is not None, \
            "paged attention needs a prefix valid_len"
        if k_view.quant:
            o = ops.paged_decode(
                q[:, 0], k_view.storage.q, v_view.storage.q,
                k_view.page_table, valid_len, softcap=logit_softcap,
                window=window, k_scale=k_view.storage.scale,
                v_scale=v_view.storage.scale)
        else:
            o = ops.paged_decode(
                q[:, 0], k_view.storage.data.astype(dtype),
                v_view.storage.data.astype(dtype), k_view.page_table,
                valid_len, softcap=logit_softcap, window=window)
        return o[:, None]
    if isinstance(k_view, LT.QuantView) and valid_len is not None and \
            kv_valid is None and ops.int8_fused_available(window):
        o = ops.int8_decode_fused(q[:, 0], k_view.q, v_view.q,
                                  k_view.scale, v_view.scale, valid_len,
                                  logit_softcap, window)
        return o[:, None]
    k = k_view.dense().astype(dtype)
    v = v_view.dense().astype(dtype)
    if kv_valid is None and valid_len is not None:
        slots = jnp.arange(k.shape[1])[None]                   # (1, S)
        kv_valid = slots < valid_len[:, None]
        w = jnp.asarray(window, jnp.int32)
        weff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
        kv_valid = jnp.logical_and(kv_valid,
                                   slots >= valid_len[:, None] - weff)
    return sdpa(q, k, v, mask=None, logit_softcap=logit_softcap,
                kv_valid=kv_valid)


def decode_attend_view(params: Params, x: jax.Array, k_view, v_view,
                       cache_len: jax.Array,
                       cos_q: Optional[jax.Array] = None,
                       sin_q: Optional[jax.Array] = None,
                       logit_softcap: float = 0.0,
                       window: "int | jax.Array" = 0):
    """Layout-native one-token decode (:func:`decode_attend` over
    KVViews): project q/k/v for the new token, append K/V *through the
    view* (paged: only the owning page is touched; int8: the vector is
    quantized in place), attend over slots ``<= cache_len`` in the
    physical representation.  Returns (out (B,1,d), k_view, v_view)."""
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    q, k_new, v_new = qkv_proj(params, x, x, dtype)
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
        k_new = apply_rope(k_new, cos_q, sin_q)
    k_view = k_view.write_token(cache_len, k_new[:, 0])
    v_view = v_view.write_token(cache_len, v_new[:, 0])
    o = _attend_views(q, k_view, v_view, valid_len=cache_len + 1,
                      logit_softcap=logit_softcap, window=window)
    return out_proj(params, o, dtype), k_view, v_view


def cross_attend_view(params: Params, x: jax.Array, k_view, v_view,
                      kv_valid: Optional[jax.Array] = None,
                      cos_q: Optional[jax.Array] = None,
                      sin_q: Optional[jax.Array] = None,
                      logit_softcap: float = 0.0,
                      valid_len: Optional[jax.Array] = None,
                      window: "int | jax.Array" = 0) -> jax.Array:
    """Layout-native :func:`cross_attend_cached`: queries attend to
    pre-projected cached K/V read through a KVView pair.  Pass EITHER a
    general (B, S) ``kv_valid`` mask (dense/int8 views only) or a prefix
    ``valid_len`` (any view, required for paged)."""
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(dtype))
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
    o = _attend_views(q, k_view, v_view, valid_len=valid_len,
                      kv_valid=kv_valid, logit_softcap=logit_softcap,
                      window=window)
    return out_proj(params, o, dtype)


def verify_attend_view(params: Params, x: jax.Array, k_view, v_view,
                       kv_valid: Optional[jax.Array] = None,
                       cos_q: Optional[jax.Array] = None,
                       sin_q: Optional[jax.Array] = None,
                       logit_softcap: float = 0.0,
                       valid_len: Optional[jax.Array] = None,
                       window: "int | jax.Array" = 0) -> jax.Array:
    """Multi-query cross-attention over a KVView pair for speculative
    VERIFY: x (B, C, d) — all C draft positions attend the resident KV
    in one dispatch.  Unlike :func:`cross_attend_view` this never takes
    the single-query paged / fused-int8 kernels (they are Lq=1 only);
    every view kind is densified and scored through the masked-safe
    :func:`sdpa`, with ``kv_valid`` (B, S) or a prefix ``valid_len``
    bounding the readable slots exactly as the sequential step would.
    """
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    q = jnp.einsum("bld,dhk->blhk", x, params["wq"].astype(dtype))
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
    k = k_view.dense().astype(dtype)
    v = v_view.dense().astype(dtype)
    if kv_valid is None and valid_len is not None:
        slots = jnp.arange(k.shape[1])[None]                   # (1, S)
        kv_valid = slots < valid_len[:, None]
        w = jnp.asarray(window, jnp.int32)
        weff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
        kv_valid = jnp.logical_and(kv_valid,
                                   slots >= valid_len[:, None] - weff)
    o = sdpa(q, k, v, mask=None, logit_softcap=logit_softcap,
             kv_valid=kv_valid)
    return out_proj(params, o, dtype)


def decode_attend(params: Params, x: jax.Array, k_cache: jax.Array,
                  v_cache: jax.Array, cache_len: jax.Array,
                  cos_q: Optional[jax.Array] = None,
                  sin_q: Optional[jax.Array] = None,
                  logit_softcap: float = 0.0,
                  window: "int | jax.Array" = 0
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode: x (B, 1, d); cache (B, S, KV, D); cache_len (B,).

    Projects q/k/v for the new token, writes k/v into the cache at
    ``cache_len``, attends over valid slots (optionally sliding-window
    limited), returns (out (B,1,d), new_k_cache, new_v_cache).
    """
    from repro.layers.rope import apply_rope
    dtype = x.dtype
    B, _, _ = x.shape
    S = k_cache.shape[1]
    q, k_new, v_new = qkv_proj(params, x, x, dtype)
    if cos_q is not None:
        q = apply_rope(q, cos_q, sin_q)
        k_new = apply_rope(k_new, cos_q, sin_q)
    # scatter the new K/V into the cache at each sequence's write index.
    # (A one-hot masked rewrite was measured to double decode-step HBM
    # traffic/peak — it reads AND writes the whole cache; scatter touches
    # one slot and updates in place under donation.)
    bidx = jnp.arange(x.shape[0])
    k_cache = k_cache.at[bidx, cache_len].set(
        k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, cache_len].set(
        v_new[:, 0].astype(v_cache.dtype))
    slots = jnp.arange(S)[None]                                # (1, S)
    valid = slots <= cache_len[:, None]
    w = jnp.asarray(window, jnp.int32)
    weff = jnp.where(w > 0, w, jnp.int32(2**30))
    valid = jnp.logical_and(valid, slots > cache_len[:, None] - weff)
    o = sdpa(q, k_cache.astype(dtype), v_cache.astype(dtype),
             mask=None, logit_softcap=logit_softcap, kv_valid=valid)
    return out_proj(params, o, dtype), k_cache, v_cache

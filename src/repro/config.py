"""Configuration system for the repro framework.

Every model is described by a :class:`ModelConfig` dataclass.  Architecture
configs live in ``repro.configs.<id>`` and register themselves under their
public ``--arch <id>`` name.  Input shapes (the four assigned workload
shapes) are described by :class:`ShapeConfig`.

The TConstFormer technique (the paper's contribution) is controlled by
``attention_mode`` + :class:`TConstConfig` and is available on every
architecture where it applies (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# TConstFormer (paper) hyper-parameters
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TConstConfig:
    """Hyper-parameters of the paper's periodic-state attention.

    Naming follows the paper: ``w_oh`` is the historical-context observation
    window, ``w_og`` the generation window, ``h`` the number of intermediate
    self-attention layers inside one TConst block.  One block has equivalent
    depth ``h + 2``; a model of equivalent depth ``L`` stacks
    ``L // (h + 2)`` blocks (paper §6.2.1: L=8 -> 2 blocks with h=2).

    The sync period k of the abstract (k=256 in the paper's example) is
    ``w_og``: after ``w_og`` generated tokens the context window slides and
    a linear-cost resync (cache miss) runs.
    """

    w_oh: int = 256
    w_og: int = 256
    h: int = 2

    @property
    def block_depth(self) -> int:
        return self.h + 2

    @property
    def w_total(self) -> int:
        return self.w_oh + self.w_og


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
ATTENTION_MODES = ("full", "sliding", "tconst", "tlin")


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "unnamed"
    arch_type: str = "dense"            # one of ARCH_TYPES
    source: str = ""                     # citation for the config

    # core transformer shape -------------------------------------------------
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4                  # GQA: kv heads <= heads
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0                    # 0 -> d_model // n_heads

    # attention behaviour ----------------------------------------------------
    attention_mode: str = "full"         # full | sliding | tconst | tlin
    sliding_window: int = 0              # >0 enables SWA when mode != tconst
    local_global_ratio: int = 0          # gemma3: N local layers per 1 global
    rope_theta: float = 10000.0
    mrope: bool = False                  # qwen2-vl multimodal rope sections
    mrope_sections: Tuple[int, ...] = ()
    logit_softcap: float = 0.0

    # normalisation / activation ----------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE ----------------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                    # expert hidden dim (deepseek fine-grained)
    first_dense_layers: int = 0          # deepseek: first k layers dense
    router_aux_coef: float = 0.01

    # SSM (mamba2 / hybrid) -----------------------------------------------------
    ssm_state: int = 0                   # state dim per head (0 = no ssm)
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (hymba): parallel attention + mamba heads in one layer
    hybrid_parallel: bool = False

    # encoder-decoder (whisper) ---------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0                 # encoder positions after conv frontend

    # modality frontend stubs ------------------------------------------------------
    frontend: str = "none"               # none | audio_stub | vision_stub
    frontend_tokens: int = 0             # patches / frames supplied by stub
    frontend_dim: int = 0                # embedding dim produced by stub

    # the paper's technique ----------------------------------------------------------
    tconst: TConstConfig = field(default_factory=TConstConfig)

    # numerics -------------------------------------------------------------------------
    dtype: str = "bfloat16"              # activation dtype
    param_dtype: str = "float32"

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def tconst_blocks(self) -> int:
        """Number of stacked TConst blocks for equivalent depth n_layers."""
        bd = self.tconst.block_depth
        return max(1, self.n_layers // bd)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.arch_type in ARCH_TYPES, self.arch_type
        assert self.attention_mode in ATTENTION_MODES, self.attention_mode
        if not self.is_attention_free:
            assert self.n_heads % self.n_kv_heads == 0, (
                f"{self.name}: n_heads {self.n_heads} not divisible by "
                f"n_kv_heads {self.n_kv_heads}")
        if self.is_moe:
            assert 0 < self.n_experts_per_tok <= self.n_experts
        if self.attention_mode == "tconst":
            assert self.n_layers % self.tconst.block_depth == 0 or \
                self.n_layers >= self.tconst.block_depth, (
                    f"{self.name}: equivalent depth {self.n_layers} not "
                    f"compatible with block depth {self.tconst.block_depth}")


# ---------------------------------------------------------------------------
# Workload shapes (the four assigned input shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str) -> Callable:
    def deco(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
        _REGISTRY[name] = fn
        return fn
    return deco


_ARCH_MODULES = [
    "mixtral_8x22b", "llama3_405b", "mamba2_130m", "deepseek_moe_16b",
    "smollm_360m", "minicpm_2b", "hymba_1_5b", "whisper_small",
    "gemma3_4b", "qwen2_vl_2b", "tconst_41m",
]


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, **overrides: Any) -> ModelConfig:
    """Look up an architecture config by its public ``--arch`` id."""
    if not _REGISTRY:
        _load_all()
    key = name.replace("-", "_").replace(".", "_")
    for cand in (name, key):
        if cand in _REGISTRY:
            cfg = _REGISTRY[cand]()
            if overrides:
                cfg = cfg.replace(**overrides)
            cfg.validate()
            return cfg
    raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def get_shape(name: str) -> ShapeConfig:
    return INPUT_SHAPES[name]


def reduced(cfg: ModelConfig, **overrides: Any) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests
    (assignment: <=2 layers equivalent scale, d_model <= 512, <= 4 experts)."""
    kw: Dict[str, Any] = dict(
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)),
        d_ff=0 if cfg.d_ff == 0 else 256,
        head_dim=0,
        vocab_size=512,
    )
    eff_mode = overrides.get("attention_mode", cfg.attention_mode)
    if eff_mode in ("tconst", "tlin"):
        kw["n_layers"] = 2 * cfg.tconst.block_depth   # 2 blocks
        kw["tconst"] = TConstConfig(w_oh=8, w_og=8, h=cfg.tconst.h)
    else:
        kw["n_layers"] = 2
    if cfg.is_moe:
        kw.update(n_experts=4, n_experts_per_tok=min(2, cfg.n_experts_per_tok),
                  moe_d_ff=64, first_dense_layers=min(1, cfg.first_dense_layers),
                  n_shared_experts=min(1, cfg.n_shared_experts))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.is_encdec:
        kw.update(encoder_layers=2, encoder_seq=16)
    if cfg.frontend != "none":
        kw.update(frontend_tokens=8, frontend_dim=32)
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    if cfg.mrope:
        kw["mrope_sections"] = (8, 4, 4)   # sums to head_dim//2 = 16
    kw.update(overrides)
    out = cfg.replace(**kw)
    out.validate()
    return out

"""Deterministic data pipeline: synthetic corpus + text-file loader +
sharded batching.

The synthetic corpus is a second-order Markov chain over a Zipf-weighted
vocabulary with long-range "topic" state — it has learnable structure at
multiple ranges, so training-loss comparisons between architectures are
meaningful (a model with better long-context pathways reaches lower loss;
used by the paper-parity benchmark).  Generation is stateless-seeded:
batch ``i`` of epoch ``e`` is reproducible from (seed, e, i) alone, so the
pipeline needs no shuffle buffers and restarts exactly after preemption
(production requirement; paired with checkpointing).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import tokenizer


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 256
    batch_size: int = 8
    seed: int = 0
    kind: str = "synthetic"          # synthetic | text
    text_path: str = ""
    n_topics: int = 16
    zipf_a: float = 1.2


class SyntheticCorpus:
    """Markov-chain corpus with topic structure (see module docstring)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, T = cfg.vocab_size, cfg.n_topics
        # Zipf-ish unigram prior per topic
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = 1.0 / ranks ** cfg.zipf_a
        self.topic_prior = np.stack([
            base[rng.permutation(V)] for _ in range(T)])
        self.topic_prior /= self.topic_prior.sum(-1, keepdims=True)
        # sparse bigram boosts per topic: each token prefers a few followers
        self.follow = rng.integers(0, V, size=(T, V, 4))
        self.topic_stay = 0.995          # long topic persistence

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        V, T = self.cfg.vocab_size, self.cfg.n_topics
        out = np.empty(n, np.int32)
        topic = int(rng.integers(T))
        prev = int(rng.integers(V))
        for i in range(n):
            if rng.random() > self.topic_stay:
                topic = int(rng.integers(T))
            if rng.random() < 0.5:       # bigram continuation
                out[i] = self.follow[topic, prev, int(rng.integers(4))]
            else:                        # topic unigram
                out[i] = rng.choice(V, p=self.topic_prior[topic])
            prev = int(out[i])
        return out


class TextCorpus:
    def __init__(self, cfg: DataConfig):
        with open(cfg.text_path, "r", encoding="utf-8",
                  errors="replace") as f:
            self.ids = tokenizer.encode(f.read())
        if cfg.vocab_size < tokenizer.VOCAB_SIZE:
            raise ValueError("vocab too small for byte tokenizer")

    def window(self, rng: np.random.Generator, n: int) -> np.ndarray:
        start = int(rng.integers(0, max(1, len(self.ids) - n - 1)))
        return self.ids[start:start + n].astype(np.int32)


def batches(cfg: DataConfig, epoch: int = 0,
            steps: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": (B, L+1)} batches — callers slice input/target."""
    corpus = TextCorpus(cfg) if cfg.kind == "text" else SyntheticCorpus(cfg)
    step = 0
    while steps is None or step < steps:
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + epoch) * 1_000_003 + step)
        rows = []
        for b in range(cfg.batch_size):
            r = np.random.default_rng(rng.integers(2**63))
            if cfg.kind == "text":
                rows.append(corpus.window(r, cfg.seq_len + 1))
            else:
                rows.append(corpus.sample(r, cfg.seq_len + 1))
        yield {"tokens": np.stack(rows)}
        step += 1

"""Byte-level tokenizer (reversible, vocab 256 + specials).

The paper trains on wikitext-103 with the GPT-2 BPE vocab; that tokenizer
is not available offline, so real text files are tokenized at byte level
and the synthetic corpus (repro.data.pipeline) emits ids directly in any
requested vocab.  PPL comparisons between architectures are unaffected by
tokenizer choice as long as it is held fixed (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3
VOCAB_SIZE = 256 + N_SPECIAL


def encode(text: str, add_bos: bool = True) -> np.ndarray:
    ids = np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(
        np.int32) + N_SPECIAL
    if add_bos:
        ids = np.concatenate([[BOS], ids]).astype(np.int32)
    return ids


def decode(ids: Iterable[int]) -> str:
    bs = bytes(int(i) - N_SPECIAL for i in ids
               if int(i) >= N_SPECIAL)
    return bs.decode("utf-8", errors="replace")

from repro.data import pipeline, tokenizer  # noqa: F401

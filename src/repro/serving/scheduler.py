"""Slot-based continuous-batching scheduler for streaming inference.

The scheduler owns one fixed-shape multi-slot ``DecodeState`` and admits
/ evicts :class:`~repro.serving.session.Session` objects mid-flight:

* **admit** — a free slot is filled by ``prefill_into_slot``: the
  session's prompt (its own length; compiled once per distinct length)
  is prefilled as a single dense row and scattered into the batched
  state.  Running slots are untouched, so a new request joins a
  half-decoded batch without disturbing it.  Under a **paged** cache
  layout the scheduler is also the page allocator: admission assigns
  just enough pool pages to cover the session's prompt + budget (the
  page table is host-side slot surgery), and a session whose pages
  aren't available yet waits in the queue — later queued sessions that
  DO fit are admitted past it (bounded skip-ahead, so the head cannot
  be starved) — so a pool sized well below ``slots * max_len`` serves
  short sessions at a fraction of the dense footprint.
* **prefix sharing (copy-on-write)** — with ``prefix_sharing=True`` the
  scheduler keeps a host-side content-addressed map from page-aligned
  prompt-token chunks to resident pool pages, with per-page refcounts.
  A session whose prompt prefix matches resident pages MAPS them into
  its ``layout__page_table`` instead of re-allocating and re-writing
  them (the admission scatter is masked to the unshared tail), so S
  sessions sharing a system prompt store its KV once.  Pages are
  writable only at refcount 1: before a chunk in which a slot's
  periodic resync may fire (``DecodeAPI.sync_anticipated``), its shared
  pages are FORKED to fresh pool pages (device-side copy, table
  surgery) — token appends never target shared pages by construction
  (only pages wholly inside ``stable_prefix_len`` enter the map).
  ``_release`` decrements refcounts; a page returns to ``free_pages``
  (and leaves the map) only at refcount 0.
* **decode** — all slots advance together in chunks of ``chunk_size``
  tokens.  A chunk is ONE jitted ``lax.scan`` over the fused step: the
  TConst W_og resync fires on device through the compacted row-wise
  ``sync_rows`` (each boundary row synced at batch size 1 — slots do
  not pay for each other's misses), so a chunk performs zero per-token
  host round-trips (one device->host transfer per chunk, for the
  sampled ids).  A slot that samples its session's EOS id sets the
  on-device ``done`` flag and is frozen for the rest of the chunk.
* **retire** — a session that exhausts its budget or hits EOS frees its
  slot at the chunk boundary (the slot's page-table row is retargeted
  at TRASH before the clearing write, so clearing can never land on a
  page another slot still references; pages whose refcount hits 0
  return to the free pool).
* **session tiering (spill / resume)** — with a
  :class:`~repro.serving.tier_store.TierStore` attached, a preempted or
  idle session SPILLS: its entire slot state is snapshotted in the
  physical representation (``DecodeState.snapshot_slot`` — int8 stays
  compressed, paged gathers only the live pages), stored host-side
  under a content digest of the session, and its slot + pool pages are
  freed.  A later admission RESUMES it into ANY free slot with one
  jitted scatter — token-identical to never having left.  With
  ``preempt_chunks=k``, slots holding their residency for >= k chunks
  are spilled round-robin whenever sessions wait, so sessions >> slots
  makes progress fairly.  The same store content-addresses two more
  things by construction: refcount-0 prefix pages RETIRE into it under
  their rolling-hash chunk keys (re-adopted — one page upload — on a
  later admission instead of re-forwarded), and families whose
  admission is a pure function of the prompt (tconst: the O(N) resync)
  cache the post-admission slot snapshot by prompt digest, so a known
  prompt re-admits as an O(1) restore with ZERO forward compute.

Chunk timings are recorded as ``StepStats(kind="chunk")`` entries (and
spills as ``kind="spill"``), admissions as ``StepStats(kind="admit")``
in ``admit_stats`` with ``source`` naming where the slot state came
from ("cold" / "resume" / "store"); entries whose wall-clock includes a
one-time jit compile carry ``compiled=True`` so aggregations
(``benchmarks/bench_inference``) can exclude them.

Two later additions layer policy on top of this mechanism:

* **pluggable scheduling policy** — the WHICH decisions (admission try
  order, pool-pressure deferral, preemption victims) live in a
  :class:`~repro.serving.policy.SchedulingPolicy`; the scheduler keeps
  the invariants (arrival-order queue, bounded overtake budget counted
  per admission past the oldest waiter — resume-sourced or cold — page
  refcounts, spill correctness) so no policy can starve or corrupt a
  session.  ``clock`` counts completed ``step()`` calls — the
  deterministic time base for SLO deadlines and telemetry
  (:class:`~repro.serving.metrics.ServingTelemetry` attaches via the
  ``telemetry`` argument and observes submit/admit/spill/token/retire).
* **per-session sampling chains** — each slot samples with its own PRNG
  key chain seeded from the session (``Session.seed``, or the scheduler
  seed folded with ``sid``), advanced once per generated token and
  carried across spill/resume, so a session's stream is a pure function
  of the session itself: replaying a workload trace is token-identical
  across runs, slot placements and scheduling policies.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time
from typing import Any, Deque, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layouts as LT
from repro.models.api import (DecodeAPI, decode_chunk, sample_tokens,
                              spec_chunk)
from repro.serving.engine import StepStats, tag_compiled
from repro.serving.metrics import ServingTelemetry
from repro.serving.policy import FifoPolicy, SchedulingPolicy, get_policy
from repro.serving.session import Session
from repro.serving.speculative import Drafter, get_drafter
from repro.serving.tier_store import (Blob, TierStore, flatten_slot_snapshot,
                                      unflatten_slot_snapshot)


class SlotScheduler:
    def __init__(self, decode: DecodeAPI, params: Any, slots: int,
                 max_len: int, chunk_size: int = 8, seed: int = 0,
                 prefix_sharing: bool = False,
                 max_head_skips: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 tier_store: Optional[TierStore] = None,
                 preempt_chunks: Optional[int] = None,
                 policy: Union[SchedulingPolicy, str, None] = None,
                 telemetry: Optional[ServingTelemetry] = None,
                 speculate: int = 0,
                 drafter: Union["Drafter", str, None] = None):
        # accept a ModelAPI facade too (duck-typed .decode)
        if not isinstance(decode, DecodeAPI) and hasattr(decode, "decode"):
            decode = decode.decode
        if slots < 1:
            raise ValueError("scheduler needs at least one decode slot")
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        if speculate < 0:
            raise ValueError("speculate must be >= 0 draft tokens")
        if speculate and not decode.supports_speculative():
            raise ValueError(
                "this model family cannot decode speculatively: rolling "
                "back rejected drafts needs state that is a pure function "
                "of a truncation point (recurrent ssm/conv state is not)")
        self.decode = decode
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_size = chunk_size
        # speculative decoding: one step() = one draft/verify round of
        # up to speculate + 1 tokens per live slot (the headroom both
        # the token buffer and the page reservation must carry)
        self.speculate = int(speculate)
        self._headroom = max(chunk_size, self.speculate + 1)
        self.drafter: Optional[Drafter] = None
        if self.speculate:
            if drafter is None:
                drafter = "ngram"
            if isinstance(drafter, str):
                drafter = get_drafter(drafter, slots=slots,
                                      vocab=decode.cfg.vocab_size,
                                      max_len=max_len, seed=seed)
            self.drafter = drafter
            self._spec = jax.jit(functools.partial(spec_chunk, decode))
        # chunked KV-conditioned admission: default rides on the decode
        # protocol (build_decode(prefill_chunk=...)); None = one-shot
        # full-prompt prefill (one compile per distinct prompt length)
        if prefill_chunk is None:
            prefill_chunk = getattr(decode, "prefill_chunk", None)
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be positive (or None "
                             "for one-shot admission)")
        self.prefill_chunk = prefill_chunk

        self.state = decode.init_state(slots, max_len)
        self.layout = self.state.layout
        # prefilled rows are always dense; slot scatter goes through the
        # batched state's layout (paged: page-map surgery)
        dense_decode = dataclasses.replace(decode, layout=LT.DENSE_SPEC)
        self._empty_row = dense_decode.init_state(1, max_len)
        self._prefill_slot = jax.jit(decode.prefill_into_slot)
        self._chunk = jax.jit(functools.partial(decode_chunk, decode),
                              static_argnames=("n_steps",))
        self._clear = jax.jit(lambda st, slot, row: st.with_slot(slot, row))

        # paged layout: the scheduler owns page assignment.  Start from an
        # all-TRASH table (a real page is writable iff its refcount is 1 —
        # the invariant the pack/scatter and the CoW fork rely on) with
        # every pool page free.  Page accounting only applies when the
        # cache actually HAS paged fields — for caches that are already
        # O(1) (pure tconst) the paged layout stores nothing in pages and
        # admission must not gate on the pool.
        self._paged = isinstance(self.layout, LT.PagedLayout) and \
            self.layout.pages_anything(self.state.kv)
        self.free_pages: List[int] = []
        self._slot_pages: List[List[int]] = [[] for _ in range(slots)]
        self._page_ref = np.zeros((0,), np.int32)
        if self._paged:
            trash = jnp.full((slots, self.layout.pages_per_slot),
                             self.layout.trash, jnp.int32)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: trash})
            self.free_pages = list(range(self.layout.pool_pages))
            self._page_ref = np.zeros((self.layout.pool_pages,), np.int32)
            self._fork = jax.jit(lambda st, src, dst: dataclasses.replace(
                st, kv=self.layout.fork_pages(st.kv, src, dst)))
        if self.prefill_chunk is not None and self._paged and \
                self.prefill_chunk % self.layout.page != 0:
            raise ValueError(
                f"prefill_chunk {self.prefill_chunk} must be a multiple "
                f"of the page size {self.layout.page} — chunk-granular "
                f"page writes cover whole pages")

        self.prefix_sharing = bool(prefix_sharing) and self._paged
        self._prefix_map: Dict[bytes, int] = {}   # chunk-chain key -> page
        self._page_key: Dict[int, bytes] = {}     # page -> its map key
        # a resyncing model (tconst/tlin) eventually FORKS every page it
        # adopted, so a sharing admission must reserve that headroom up
        # front — otherwise admission could overcommit the pool into a
        # state where no slot can ever fork (LM families never fork)
        self._fork_reserve = self.prefix_sharing and bool(
            np.any(self.decode.sync_anticipated(self.state, 1 << 30)))
        self._key_cache: Dict[int, List[bytes]] = {}   # sid -> chunk keys
        # bounded skip-ahead: how many sessions may be admitted past a
        # page-blocked queue head before admission stops overtaking it
        # (freed pages then necessarily reach the head: eventual FIFO)
        self.max_head_skips = 4 * slots if max_head_skips is None \
            else max_head_skips
        self._head_skips = 0

        # session tiering: host-side content-addressed store + preemption
        if preempt_chunks is not None and preempt_chunks < 1:
            raise ValueError("preempt_chunks must be positive (or None to "
                             "disable preemptive spilling)")
        if preempt_chunks is not None and tier_store is None:
            raise ValueError("preempt_chunks needs a tier_store to spill "
                             "preempted sessions into")
        self.store = tier_store
        self.preempt_chunks = preempt_chunks
        self.spill_stats = {"spills": 0, "resumes": 0, "spilled_bytes": 0,
                            "pages_retired": 0, "pages_readopted": 0,
                            "admit_store_hits": 0, "admit_store_puts": 0}
        # chunks each slot has decoded since its current residency began
        # (admit/resume resets it) — the preemption ripeness clock
        self._slot_chunks = np.zeros((slots,), np.int64)
        if self._paged:
            self._page_axes = {f: self.layout.page_axis(f)
                               for f in self.state.kv
                               if self.layout.page_axis(f) is not None}
        else:
            self._page_axes = {}
        if self.store is not None:
            self._snap = jax.jit(lambda st, slot: st.snapshot_slot(slot))
            self._restore = jax.jit(
                lambda st, slot, snap: st.restore_slot(slot, snap))
            if self._paged:
                self._gather_pages = jax.jit(
                    lambda st, idx: self.layout.gather_pages(st.kv, idx))
                self._scatter_pages = jax.jit(
                    lambda st, idx, contents: dataclasses.replace(
                        st, kv=self.layout.scatter_pages(st.kv, idx,
                                                         contents)))

        # per-slot sampling key chains: row i is the NEXT key of the
        # session in slot i, advanced on device once per live decode
        # step (decode_chunk's per-slot mode) and seeded per session at
        # admission — never from slot position or batch composition.
        self._base_key = jax.random.PRNGKey(seed)
        self.slot_keys = jnp.zeros((slots, 2), jnp.uint32)
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.temps = np.zeros((slots,), np.float32)
        self.eos = np.full((slots,), -1, np.int32)
        self.active = np.zeros((slots,), bool)
        self.sessions: List[Optional[Session]] = [None] * slots
        self.pending: Deque[Session] = collections.deque()
        self.stats: List[StepStats] = []
        self.admit_stats: List[StepStats] = []
        self._warm: set = set()       # (kind, signature) -> compiled tag

        # policy seam + telemetry + deterministic clock (chunk units)
        if policy is None:
            policy = FifoPolicy()
        elif isinstance(policy, str):
            policy = get_policy(policy)
        self.policy = policy
        self.telemetry = telemetry
        self.clock = 0                # completed step() calls

    # ------------------------------------------------------------------
    def _pages_needed(self, session: Session) -> int:
        need = len(session.prompt) + session.max_new_tokens + self._headroom
        return -(-need // self.layout.page)

    def submit(self, session: Session) -> Session:
        """Queue a session; it is admitted at the next chunk boundary."""
        # decode writes token ids into the slot's fixed (max_len,) buffer;
        # an overflowing write would be silently dropped by the scatter and
        # corrupt the next resync, so reject oversized requests up front
        # (headroom: a session may overshoot its budget by up to one
        # chunk — or one speculate+1 verify round — before it is retired
        # at the boundary, and a verify round WRITES all speculate+1
        # positions before acceptance truncates).
        need = len(session.prompt) + session.max_new_tokens + self._headroom
        if need > self.max_len:
            raise ValueError(
                f"session {session.sid}: prompt {len(session.prompt)} + "
                f"max_new_tokens {session.max_new_tokens} (+ headroom "
                f"{self._headroom}) exceeds max_len {self.max_len}")
        # total-pool capacity check: a session needing more pages than the
        # POOL holds would pass a max_len-only check but could never be
        # admitted, leaving run() to spin on it forever
        if self._paged and \
                self._pages_needed(session) > self.layout.pool_pages:
            raise ValueError(
                f"session {session.sid}: needs {self._pages_needed(session)}"
                f" pages but the paged pool only has "
                f"{self.layout.pool_pages} — it could never be admitted")
        session.submit_clock = self.clock
        self.pending.append(session)
        if self.telemetry is not None:
            self.telemetry.on_submit(session, self.clock)
        return session

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def kv_bytes(self) -> int:
        """GLOBAL physical KV bytes — under a sharded pool this is the
        whole-fleet figure, not one shard's buffer (sharded jax Arrays
        report global shapes; regression-tested in
        ``tests/test_sharded_decode.py``)."""
        return self.state.kv_bytes()

    def assigned_kv_bytes(self) -> int:
        """KV bytes the live page tables reference — a prefix-shared
        page is counted once (see ``DecodeState.assigned_kv_bytes``).
        GLOBAL bytes under a sharded pool, identical to the 1-device
        run; telemetry pool-occupancy shares the same guarantee (its
        free/total page counts come from the host-side allocator, which
        tracks logical — global — pages)."""
        return self.state.assigned_kv_bytes()

    def per_device_kv_bytes(self) -> int:
        """Largest per-device share of the physical KV buffers —
        ≈ ``kv_bytes() / model_shards`` for the head-sharded decode
        layout, equal to ``kv_bytes()`` unmeshed."""
        return self.state.per_device_kv_bytes()

    def page_refcounts(self) -> np.ndarray:
        """Host-side per-page refcounts (copy); all zeros when idle."""
        return self._page_ref.copy()

    def spill_cost(self, slot: int) -> Dict[str, int]:
        """Estimated cost of evicting the session in ``slot``, for
        cost-aware victim selection: ``bytes`` is the snapshot the spill
        would move to the host tier (paged: live pages only — a tconst
        slot's physical KV is O(1)-small; dense LM: the full per-slot
        row), ``readmit`` the bytes a LATER fresh admission of the same
        request would cost — zero for families whose admission is a pure
        function of the prompt (``DecodeAPI.admission_key`` non-None:
        re-admission is an O(1) store restore), else the snapshot again.
        Host-side arithmetic only — no device work."""
        session = self.sessions[slot]
        assert session is not None, "spill_cost needs an occupied slot"
        snap_bytes = 0
        if self._paged:
            live = self._live_pages(session)
            for f, v in self.state.kv.items():
                ax = self._page_axes.get(f)
                if ax is not None:
                    snap_bytes += (v.nbytes // v.shape[ax]) * live
                else:
                    snap_bytes += v.nbytes // self.slots
        else:
            snap_bytes = self.kv_bytes() // self.slots
        pure = self.decode.admission_key(session.prompt,
                                         session.extras) is not None
        readmit = 0 if pure else snap_bytes
        return {"bytes": int(snap_bytes), "readmit": int(readmit),
                "total": int(snap_bytes + readmit)}

    # ------------------------------------------------------------------
    # prefix sharing: content-addressed page map + refcounts
    # ------------------------------------------------------------------
    def _chunk_keys(self, session: Session) -> List[bytes]:
        """Rolling content-addressed keys for the page-aligned prompt
        chunks inside the session's stable prefix.  Key i covers
        ``prompt[:(i+1)*page]`` — KV content at a position is a causal
        function of ALL preceding tokens — salted with a digest of the
        per-request extras (encoder memory / vision inputs feed the
        same KV, so sessions with different extras must never match)."""
        cached = self._key_cache.get(session.sid)
        if cached is not None:
            return cached
        page = self.layout.page
        stable = self.decode.stable_prefix_len(len(session.prompt))
        n = min(stable, len(session.prompt)) // page
        h = hashlib.sha1()
        if session.extras:
            for name in sorted(session.extras):
                h.update(name.encode())
                h.update(np.asarray(session.extras[name]).tobytes())
        prompt = np.ascontiguousarray(session.prompt, np.int32)
        keys = []
        for i in range(n):
            h.update(prompt[i * page:(i + 1) * page].tobytes())
            keys.append(h.copy().digest())
        # prompt/extras are immutable after submit: memoize so a blocked
        # queue doesn't re-hash megabyte extras once per chunk
        self._key_cache[session.sid] = keys
        return keys

    def _register(self, key: bytes, page: int) -> None:
        self._prefix_map[key] = page
        self._page_key[page] = key

    def _unregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            self._prefix_map.pop(key, None)

    def _set_table_row(self, slot: int, pages: List[int]) -> None:
        self._slot_pages[slot] = list(pages)
        row = np.full((self.layout.pages_per_slot,), self.layout.trash,
                      np.int32)
        row[:len(pages)] = pages
        pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(
            jnp.asarray(row))
        self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})

    # ------------------------------------------------------------------
    # session tiering: spill / resume / retire through the TierStore
    # ------------------------------------------------------------------
    def _store_salt(self) -> bytes:
        """Scheduler-level key salt: snapshot shapes and admission
        numerics depend on max_len, the bound layout and the prefill
        path, so schedulers differing in any of them must never share
        store entries.  (Params identity is NOT hashed — a TierStore
        must not be shared across schedulers serving different
        weights.)"""
        return f"{self.max_len}|{self.layout!r}|{self.prefill_chunk}" \
            .encode()

    def _session_key(self, session: Session) -> bytes:
        """Content digest of a session's CURRENT state: extras + prompt
        + every token generated so far.  Two sessions at the same point
        of the same request share one snapshot entry (pin counts
        nest)."""
        h = hashlib.sha1(b"session\x00" + self._store_salt())
        if session.extras:
            for name in sorted(session.extras):
                h.update(name.encode())
                h.update(np.asarray(session.extras[name]).tobytes())
        h.update(np.ascontiguousarray(session.prompt, np.int32).tobytes())
        h.update(np.asarray(session.tokens, np.int32).tobytes())
        return h.digest()

    def _admission_key(self, session: Session) -> Optional[bytes]:
        """Store key of this request's post-admission slot state, or
        None when the family's admission is not a pure function of the
        prompt ids (``DecodeAPI.admission_key``) or there is no store."""
        if self.store is None:
            return None
        base = self.decode.admission_key(session.prompt, session.extras)
        if base is None:
            return None
        h = hashlib.sha1(b"admit\x00" + self._store_salt())
        h.update(base)
        return h.digest()

    def _live_pages(self, session: Session) -> int:
        """Pages that can hold WRITTEN content for this session right
        now (prompt + generated ids, one page-granule of slack) — the
        honest host-tier size of a paged spill; the pages beyond it in
        the slot's allocation hold nothing a restore needs."""
        need = len(session.prompt) + len(session.tokens) + 1
        return -(-need // self.layout.page)

    def _snapshot_slot_host(self, slot: int, n_keep: Optional[int] = None
                            ) -> Dict[str, Any]:
        """Device snapshot of ``slot`` pulled to host, with paged page
        stacks trimmed to the first ``n_keep`` table entries (the live
        prefix of the slot's allocation)."""
        snap = jax.device_get(self._snap(self.state, np.int32(slot)))
        if self._paged and n_keep is not None:
            n_keep = min(n_keep, len(self._slot_pages[slot]))
            for f, ax in self._page_axes.items():
                snap["kv"][f] = np.take(snap["kv"][f], np.arange(n_keep),
                                        axis=ax)
        return snap

    def _pad_kv_snapshot(self, kv: Dict[str, Any]) -> Dict[str, Any]:
        """Pad trimmed paged page stacks back to pages_per_slot (zeros —
        they scatter onto unwritten pages, masked until written) so the
        jitted restore has ONE fixed shape."""
        out = {}
        pps = self.layout.pages_per_slot if self._paged else 0
        for f, v in kv.items():
            ax = self._page_axes.get(f)
            if ax is not None and v.shape[ax] < pps:
                widths = [(0, 0)] * v.ndim
                widths[ax] = (0, pps - v.shape[ax])
                v = np.pad(np.asarray(v), widths)
            out[f] = jnp.asarray(v)
        return out

    def spill(self, slot: int) -> bytes:
        """Spill the active session in ``slot`` to the tier store:
        snapshot its entire slot state (physical representation — int8
        stays compressed, paged holds only live pages), PIN it under the
        session's content digest, free the slot and its pool pages, and
        re-queue the session.  A later admission restores it into ANY
        free slot, token-identical to never having left.  Returns the
        store key."""
        assert self.store is not None, "spilling needs a tier_store"
        session = self.sessions[slot]
        assert session is not None and not session.done, \
            "can only spill a live session"
        t0 = time.perf_counter()
        snap = self._snapshot_slot_host(
            slot, self._live_pages(session) if self._paged else None)
        blob = flatten_slot_snapshot(snap, {
            "kind": "session", "sid": session.sid,
            "last_token": int(np.asarray(self.last_token[slot]))})
        key = self._session_key(session)
        self.store.put(key, blob, pin=True)
        self.stats.append(StepStats(
            "spill", time.perf_counter() - t0,
            tokens=len(session.prompt) + len(session.tokens),
            compiled=tag_compiled(self._warm, "spill")))
        session.snap_key = key
        session.spills += 1
        session.slot = None
        # freeze the session's sampling chain at its current position
        # (= len(session.tokens)) so resume continues the exact stream
        session.sample_chain = np.asarray(self.slot_keys[slot])
        self.spill_stats["spills"] += 1
        self.spill_stats["spilled_bytes"] += blob.nbytes
        self._release(slot)
        self.pending.append(session)
        if self.telemetry is not None:
            self.telemetry.on_spill(session, self.clock)
        return key

    def _resume(self, session: Session, slot: int,
                plan: Dict[str, Any]) -> None:
        """Admission path for a spilled session: allocate fresh private
        pages, restore the pinned snapshot into ``slot`` with ONE jitted
        scatter, and unpin.  No prefill, no sampling — the session's
        last sampled token rides in the snapshot meta and decode picks
        up exactly where it left off."""
        blob = self.store.get(session.snap_key)
        assert blob is not None, \
            "pinned session snapshot disappeared from the tier store"
        bk_rows, kv_rows, meta = unflatten_slot_snapshot(blob)
        if self._paged:
            fresh = [self.free_pages.pop() for _ in range(plan["total"])]
            for p in fresh:
                self._page_ref[p] = 1
            self._set_table_row(slot, fresh)
        t0 = time.perf_counter()
        dev = {"bookkeeping": {n: jnp.asarray(np.asarray(v))
                               for n, v in bk_rows.items()},
               "kv": self._pad_kv_snapshot(kv_rows)}
        self.state = self._restore(self.state, np.int32(slot), dev)
        jax.block_until_ready(self.state.kv)
        self.admit_stats.append(StepStats(
            "admit", time.perf_counter() - t0,
            tokens=len(session.prompt) + len(session.tokens),
            compiled=tag_compiled(self._warm, "admit", ("resume",)),
            forward_tokens=0, source="resume"))
        self.store.unpin(session.snap_key)
        session.snap_key = None
        session.resumes += 1
        self.spill_stats["resumes"] += 1
        self.last_token = self.last_token.at[slot].set(
            np.int32(meta["last_token"]))
        # resume the sampling chain exactly where the spill froze it
        self.slot_keys = self.slot_keys.at[slot].set(
            jnp.asarray(session.sample_chain))
        session.sample_chain = None
        session.slot = slot
        self.sessions[slot] = session
        self.active[slot] = True
        self.temps[slot] = session.temperature
        self.eos[slot] = -1 if session.eos_id is None else session.eos_id
        self._slot_chunks[slot] = 0
        if self.drafter is not None:
            # re-seed the drafter with the full resumed stream
            self.drafter.admit(slot, list(session.prompt) + session.tokens)
        if self.telemetry is not None:
            self.telemetry.on_admit(session, self.clock, "resume")

    def _retire_pages(self, retiring: List) -> None:
        """Refcount-0 prefix pages RETIRE into the tier store instead of
        vanishing with their map entry (the pre-tiering bug): their
        content stays re-adoptable — LRU-evictable, unpinned — under the
        same rolling-hash chunk key, so residency in the memory
        hierarchy, not refcount, decides whether a later admission
        re-forwards the prefix.  ``retiring`` is [(page, key), ...] for
        pages ABOUT to be recycled; the gather runs before anything can
        reallocate them."""
        pps = self.layout.pages_per_slot
        idx = np.full((pps,), self.layout.trash, np.int32)
        for i, (p, _) in enumerate(retiring):
            idx[i] = p
        gathered = jax.device_get(
            self._gather_pages(self.state, jnp.asarray(idx)))
        for i, (_, key) in enumerate(retiring):
            arrays = {f: np.take(v, np.arange(i, i + 1),
                                 axis=self._page_axes[f])
                      for f, v in gathered.items()}
            self.store.put(key, Blob(arrays, {"kind": "page"}))
        self.spill_stats["pages_retired"] += len(retiring)

    def _fetch_restorable(self, keys: List[bytes]) -> List[Blob]:
        """Fetch the planned re-adoptable page blobs; a key that aged
        out between plan and admit just truncates the restorable run —
        the tail goes back to cold prefill (page counts are unchanged:
        restorable pages come from the free pool either way)."""
        blobs: List[Blob] = []
        for k in keys:
            b = self.store.get(k)
            if b is None:
                break
            blobs.append(b)
        return blobs

    def _upload_pages(self, page_ids: List[int],
                      blobs: List[Blob]) -> None:
        """Scatter retired-page content from the store onto freshly
        allocated pool pages (one fixed-arity jitted write) — the
        re-adoption that replaces re-forwarding the prefix."""
        pps = self.layout.pages_per_slot
        idx = np.full((pps,), self.layout.trash, np.int32)
        idx[:len(page_ids)] = page_ids
        contents = {}
        for f, ax in self._page_axes.items():
            stack = np.concatenate(
                [np.asarray(b.arrays[f]) for b in blobs], axis=ax)
            if stack.shape[ax] < pps:
                widths = [(0, 0)] * stack.ndim
                widths[ax] = (0, pps - stack.shape[ax])
                stack = np.pad(stack, widths)
            contents[f] = jnp.asarray(stack)
        self.state = self._scatter_pages(self.state, jnp.asarray(idx),
                                         contents)
        self.spill_stats["pages_readopted"] += len(blobs)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admission_plan(self, session: Session) -> Optional[Dict[str, Any]]:
        """The pages this admission would take, or None if it must wait
        for the free pool.  With prefix sharing, resident pages matching
        the session's prompt-prefix chunks are adopted instead of drawn
        from the free pool; with a tier store, chunk keys whose pages
        RETIRED are planned for re-adoption (fresh page + content
        upload) and a spilled session / store-hit prompt plans an
        all-fresh restore."""
        resume = session.snap_key is not None
        admit_key = None if resume else self._admission_key(session)
        # a restore scatters the WHOLE slot, so it must own every page
        # privately — no adoption; the store probe must not touch LRU
        admit_hit = admit_key is not None and admit_key in self.store
        if not self._paged:
            return {"total": 0, "adopted": [], "keys": [],
                    "restorable": [], "resume": resume,
                    "admit_key": admit_key, "admit_hit": admit_hit}
        total = self._pages_needed(session)
        keys = [] if (resume or admit_hit or not self.prefix_sharing) \
            else self._chunk_keys(session)
        adopted: List[int] = []
        for key in keys:
            page = self._prefix_map.get(key)
            if page is None:
                break
            adopted.append(page)
        # beyond the resident run, contiguous chunk keys whose pages
        # retired into the store are re-adoptable: they still need a
        # fresh page each (counted in total - adopted), plus an upload
        restorable: List[bytes] = []
        if self.store is not None:
            for key in keys[len(adopted):]:
                if key in self.store:
                    restorable.append(key)
                else:
                    break
        # resyncing models: adopted pages will be forked before this
        # slot's first resync, so their copies count against the pool now
        reserve = len(adopted) if self._fork_reserve else 0
        if total - len(adopted) + reserve > len(self.free_pages):
            return None                # wait for running sessions to retire
        return {"total": total, "adopted": adopted, "keys": keys,
                "restorable": restorable, "resume": resume,
                "admit_key": admit_key, "admit_hit": admit_hit}

    def _admit(self, session: Session, slot: int,
               plan: Dict[str, Any]) -> None:
        if plan.get("resume"):
            self._resume(session, slot, plan)
            return
        admit_blob = None
        if plan.get("admit_hit"):
            # fetch FIRST (nothing else touches the store before this):
            # None means the entry aged out since planning — the plan's
            # all-fresh pages make the cold path below still valid
            admit_blob = self.store.get(plan["admit_key"])
        mask = None
        n_resident = 0
        if self._paged:
            n_adopt = len(plan["adopted"])
            readopt = self._fetch_restorable(plan.get("restorable", [])) \
                if admit_blob is None else []
            fresh = [self.free_pages.pop()
                     for _ in range(plan["total"] - n_adopt)]
            pages = list(plan["adopted"]) + fresh
            for p in plan["adopted"]:
                self._page_ref[p] += 1
            for p in fresh:
                self._page_ref[p] = 1
            self._set_table_row(slot, pages)
            n_rest = len(readopt)
            if n_rest:
                # upload retired prefix-page content from the store onto
                # this slot's fresh pages BEFORE the prefill, so the
                # chunk loop attends it instead of re-forwarding it
                self._upload_pages(pages[n_adopt:n_adopt + n_rest],
                                   readopt)
            n_resident = n_adopt + n_rest
            if self.prefix_sharing:
                # register this prompt's freshly written stable pages so
                # later sessions can adopt them (adopted ones already
                # are; re-adopted ones re-enter the map resident)
                for i, key in enumerate(plan["keys"]):
                    if key not in self._prefix_map:
                        self._register(key, pages[i])
                if n_resident:
                    # tail-only admission write: resident pages hold the
                    # identical (content-addressed) KV already — and CoW
                    # says never write a page with refcount > 1
                    host_mask = np.ones((self.layout.pages_per_slot,), bool)
                    host_mask[:n_resident] = False
                    mask = jnp.asarray(host_mask)
        resident = n_resident * self.layout.page if self._paged else 0
        chunked = self.prefill_chunk is not None and \
            self.decode.supports_chunked_prefill(session.extras) and \
            self.decode.chunked_prefill_fits(
                len(session.prompt), resident, self.prefill_chunk,
                self.max_len)
        extras_sig = tuple(sorted(
            (k, tuple(np.shape(v))) for k, v in (session.extras or {}).items()))
        t0 = time.perf_counter()
        if admit_blob is not None:
            # content-addressed admission-cache hit: the whole
            # post-prefill slot state (+ its logits) restores in ONE
            # jitted scatter — the O(N) resync/prefill never runs
            bk_rows, kv_rows, _ = unflatten_slot_snapshot(admit_blob)
            dev = {"bookkeeping": {n: jnp.asarray(np.asarray(v))
                                   for n, v in bk_rows.items()},
                   "kv": self._pad_kv_snapshot(kv_rows)}
            self.state = self._restore(self.state, np.int32(slot), dev)
            logits = jnp.asarray(np.asarray(admit_blob.arrays["logits"]))
            fwd = 0
            sig = ("admit_restore", extras_sig)
            source = "store"
            self.spill_stats["admit_store_hits"] += 1
        elif chunked:
            # KV-conditioned chunked admission: forward compute covers
            # only the unshared tail (adopted pages are attended, not
            # recomputed... except the one chunk the logits need), and
            # every dispatch has a fixed shape — the compile signature
            # is the BUCKET (chunk size x variants), not the prompt
            # length, so K distinct lengths share one compiled set.
            logits, self.state, info = self.decode.prefill_into_slot_chunked(
                self.params, self.state, np.int32(slot), session.prompt,
                extras=session.extras, page_write_mask=mask,
                resident_len=resident, chunk=self.prefill_chunk)
            fwd = info["forward_tokens"]
            sig = ("chunked", self.prefill_chunk, resident > 0,
                   mask is not None, extras_sig)
            source = "cold"
        else:
            logits, self.state = self._prefill_slot(
                self.params, self.state, np.int32(slot),
                jnp.asarray(session.prompt), extras=session.extras,
                page_write_mask=mask)
            fwd = len(session.prompt)
            # the one-shot prefill retraces on any shape change: prompt
            # length, mask presence, AND extras shapes
            sig = (len(session.prompt), mask is not None, extras_sig)
            source = "cold"
        logits = jax.block_until_ready(logits)
        self._key_cache.pop(session.sid, None)
        self.admit_stats.append(StepStats(
            "admit", time.perf_counter() - t0, tokens=len(session.prompt),
            compiled=tag_compiled(self._warm, "admit", sig),
            forward_tokens=fwd, source=source))
        if admit_blob is None and plan.get("admit_key") is not None:
            # cacheable cold admission: the just-admitted slot state is a
            # pure function of the prompt — snapshot it (pre-sampling)
            # with its logits so the NEXT admission of this prompt is an
            # O(1) restore.  Unpinned: LRU decides how long it lives.
            snap = self._snapshot_slot_host(
                slot, self._live_pages(session) if self._paged else None)
            blob = flatten_slot_snapshot(snap, {"kind": "admit"})
            blob.arrays["logits"] = np.asarray(logits)
            self.store.put(plan["admit_key"], blob)
            self.spill_stats["admit_store_puts"] += 1
        # per-session sampling chain: seeded from the session (never
        # from slot position / batch composition), advanced once here
        # for the first token and once per live step on device after —
        # so the chain position is always the generated-token count and
        # the stream replays identically across runs and policies.
        chain = jax.random.PRNGKey(session.seed) if session.seed is not None \
            else jax.random.fold_in(self._base_key, session.sid)
        pair = jax.random.split(chain)
        t0k = sample_tokens(logits[None],
                            jnp.full((1,), session.temperature),
                            pair[1][None])[0]
        self.slot_keys = self.slot_keys.at[slot].set(pair[0])
        self.last_token = self.last_token.at[slot].set(t0k)
        session.slot = slot
        self.sessions[slot] = session
        self.active[slot] = True
        self.temps[slot] = session.temperature
        self.eos[slot] = -1 if session.eos_id is None else session.eos_id
        self._slot_chunks[slot] = 0
        if self.telemetry is not None:
            self.telemetry.on_admit(session, self.clock, source)
        session.deliver([int(t0k)])          # first token: prefill logits
        if self.drafter is not None:
            # the drafter's window = prompt + everything delivered
            self.drafter.admit(slot, list(session.prompt) + session.tokens)
        if self.telemetry is not None:
            self.telemetry.on_tokens(session, len(session.tokens),
                                     self.clock,
                                     self.admit_stats[-1].compiled)

    def admit_pending(self) -> bool:
        """Admit as many pending sessions as free slots/pages allow.

        The policy proposes the try order (``order_pending``; FIFO for
        the baseline) and may defer admissible non-head sessions
        (``defer_admission``); the scheduler enforces fairness around
        it: EVERY admission of a session other than the arrival-order
        head — skip-ahead past a page-blocked head, policy reordering,
        or a resume-sourced re-admission of a spilled session — counts
        one overtake against ``max_head_skips``, and a spent budget
        forces strict arrival order until the head admits (freed pages
        then necessarily reach it: eventual FIFO, no starvation).  The
        overtake count is per admitted IDENTITY, not queue position —
        position-based accounting (the pre-policy code) undercounts
        once resumes re-enter at the tail and a policy reorders the try
        list.  Returns True if any session was admitted."""
        free = [i for i in range(self.slots) if not self.active[i]]
        admitted = False
        while free and self.pending:
            head = self.pending[0]
            if self._head_skips >= self.max_head_skips:
                candidates: List[Session] = [head]   # budget spent
            else:
                candidates = self.policy.order_pending(
                    list(self.pending), self)
            chosen = None
            plan = None
            for cand in candidates:
                p = self._admission_plan(cand)
                if p is None:
                    continue           # blocked on pool pages — try next
                if cand is not head and \
                        self.policy.defer_admission(self, cand, p):
                    continue           # policy holds it back (never head)
                chosen, plan = cand, p
                break
            if chosen is None:
                break                  # nothing admissible this round
            for i, s in enumerate(self.pending):
                if s is chosen:        # identity, not __eq__ (ndarrays)
                    del self.pending[i]
                    break
            if chosen is head:
                self._head_skips = 0
            else:
                self._head_skips += 1
            slot = free.pop(0)
            self._admit(chosen, slot, plan)
            admitted = True
            if chosen.done:
                self._release(slot)
                free.insert(0, slot)
                if self.telemetry is not None:
                    self.telemetry.on_retire(chosen, self.clock)
        if not self.pending:
            self._head_skips = 0
        return admitted

    # ------------------------------------------------------------------
    # copy-on-write forking (chunk boundary)
    # ------------------------------------------------------------------
    def _cow_before_chunk(self) -> np.ndarray:
        """A page is writable iff refcount == 1.  The only device-side
        writes that can target resident prefix pages are the periodic
        resync's KV rebuild (token appends land beyond the stable
        prefix by construction), so any active slot whose resync may
        fire within the next chunk is made page-private NOW.  A slot
        that cannot fork (no free pages for the copies) is PAUSED for
        this chunk — masked out of the dispatch, frozen bit-identically
        — and retried once retiring sessions free pages.  Admission's
        fork reserve is checked per-admission against the instantaneous
        free pool (commitments are not tracked across slots — e.g. a
        slot's pages become shared only when a LATER session adopts
        them), so pausing is the backstop that keeps in-flight sessions
        alive instead of crashing them.  Returns the (B,) mask of slots
        that actually decode this chunk."""
        run_mask = self.active.copy()
        anticipated = self.decode.sync_anticipated(self.state,
                                                   self._headroom)
        for slot in np.nonzero(self.active)[0]:
            if anticipated[slot] and not self._make_slot_private(int(slot)):
                run_mask[slot] = False
        return run_mask

    def _make_slot_private(self, slot: int) -> bool:
        """Fork the slot's shared pages to fresh ones; True on success,
        False when the free pool cannot back the copies (caller pauses
        the slot — forking later is always still correct)."""
        pages = self._slot_pages[slot]
        shared = [j for j, p in enumerate(pages) if self._page_ref[p] > 1]
        if len(shared) > len(self.free_pages):
            return False
        for p in pages:
            if self._page_ref[p] == 1:
                # sole owner about to rewrite the page: its content may
                # stop matching the registered token prefix — retract it
                self._unregister(p)
        if not shared:
            return True
        fresh = [self.free_pages.pop() for _ in shared]
        pps = self.layout.pages_per_slot
        src = np.full((pps,), self.layout.trash, np.int32)
        dst = np.full((pps,), self.layout.trash, np.int32)
        for k, (j, p_new) in enumerate(zip(shared, fresh)):
            src[k], dst[k] = pages[j], p_new
        self.state = self._fork(self.state, jnp.asarray(src),
                                jnp.asarray(dst))
        for j, p_new in zip(shared, fresh):
            self._page_ref[pages[j]] -= 1
            self._page_ref[p_new] = 1
            pages[j] = p_new
        self._set_table_row(slot, pages)
        return True

    # ------------------------------------------------------------------
    def _release(self, slot: int) -> None:
        if self.drafter is not None:
            self.drafter.release(slot)
        self.sessions[slot] = None
        self.active[slot] = False
        self.temps[slot] = 0.0
        self.eos[slot] = -1
        if self._paged:
            # retarget the table row at TRASH before the clearing write
            # below, so clearing zeros can never land on a page another
            # slot still references (prefix sharing); then drop refs —
            # a page is recycled (and leaves the prefix map) only at 0
            trash_row = jnp.full((self.layout.pages_per_slot,),
                                 self.layout.trash, jnp.int32)
            pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(trash_row)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})
            retiring = []
            for p in self._slot_pages[slot]:
                self._page_ref[p] -= 1
                if self._page_ref[p] == 0:
                    # tiering bugfix: a refcount-0 prefix page used to
                    # leave the content map the moment it recycled —
                    # with a store it retires INTO the tier instead
                    # (gathered below, before anything can reuse it)
                    key = self._page_key.get(p)
                    if self.store is not None and key is not None:
                        retiring.append((p, key))
                    self._unregister(p)
                    self.free_pages.append(p)
            self._slot_pages[slot] = []
            if retiring:
                self._retire_pages(retiring)
        # clear the slot so stale phase counters can't keep firing the
        # on-device resync for an empty row (paged: the writes land on
        # the trash page — the slot no longer owns real pages)
        self.state = self._clear(self.state, np.int32(slot),
                                 self._empty_row)
        self.last_token = self.last_token.at[slot].set(0)

    # ------------------------------------------------------------------
    def _preempt_for_pending(self) -> int:
        """Preemption: when sessions still wait after admission (blocked
        on slots OR pool pages), active sessions that have decoded at
        least ``preempt_chunks`` chunks this residency are spill
        CANDIDATES — the policy picks the victims (baseline: longest-
        resident first; the SLO policy: cheapest by ``spill_cost``),
        one per waiter.  A fresh residency always decodes >=
        preempt_chunks before it can be preempted again, so every
        rotation makes progress and the oversubscribed queue drains
        fairly regardless of the victim order."""
        ripe = [s for s in range(self.slots)
                if self.active[s]
                and self._slot_chunks[s] >= self.preempt_chunks]
        n = min(len(ripe), len(self.pending))
        if not n:
            return 0
        victims = self.policy.select_victims(self, ripe, n)[:n]
        for s in victims:
            self.spill(int(s))
        return len(victims)

    def _tick_telemetry(self) -> None:
        if self.telemetry is None:
            return
        self.telemetry.on_tick(
            self.clock, self.n_active, len(self.pending),
            len(self.free_pages) if self._paged else None,
            self.layout.pool_pages if self._paged else None)

    def step(self) -> bool:
        """Admit pending sessions, then decode ONE chunk for the active
        slots (a single dispatch; slots paused for copy-on-write fork
        headroom are masked out, frozen bit-identically).  With a tier
        store and ``preempt_chunks`` set, slots are preemptively spilled
        for waiting sessions first.  Each call advances ``clock`` by one
        — the deterministic time base for SLO deadlines and telemetry.
        Returns False when no progress was made — nothing admitted and
        nothing could decode."""
        self.clock += 1
        admitted = self.admit_pending()
        if self.store is not None and self.preempt_chunks is not None \
                and self.pending:
            if self._preempt_for_pending():
                admitted = self.admit_pending() or admitted
        if not self.active.any():
            self._tick_telemetry()
            return admitted
        run_mask = self._cow_before_chunk() if self.prefix_sharing \
            else self.active
        if not run_mask.any():
            self._tick_telemetry()
            return admitted            # every active slot fork-paused
        if self.speculate:
            return self._spec_step(run_mask) or admitted
        t0 = time.perf_counter()
        toks, self.state, self.slot_keys = self._chunk(
            self.params, self.state, self.last_token, self.slot_keys,
            jnp.asarray(self.temps), jnp.asarray(run_mask),
            n_steps=self.chunk_size, eos=jnp.asarray(self.eos))
        self.last_token = toks[:, -1]
        host_toks = np.asarray(toks)         # the ONE host sync per chunk
        compiled = tag_compiled(self._warm, "chunk")
        self.stats.append(StepStats(
            "chunk", time.perf_counter() - t0, tokens=self.chunk_size,
            compiled=compiled))
        for slot in np.nonzero(run_mask)[0]:
            self._slot_chunks[slot] += 1
            sess = self.sessions[slot]
            before = len(sess.tokens)
            sess.deliver(host_toks[slot])
            if self.telemetry is not None:
                self.telemetry.on_tokens(sess, len(sess.tokens) - before,
                                         self.clock, compiled)
            if sess.done:
                self._release(slot)
                if self.telemetry is not None:
                    self.telemetry.on_retire(sess, self.clock)
        self._tick_telemetry()
        return True

    def _spec_step(self, run_mask: np.ndarray) -> bool:
        """One speculative round for the running slots: the drafter
        proposes k tokens per slot, ONE ``spec_chunk`` dispatch verifies
        them all against the resident KV, and each live slot commits its
        verify-exact accepted prefix + bonus token (1..k+1 tokens).  The
        per-slot key chains advance by exactly the accepted counts, so
        streams stay token-identical to the non-speculative run — the
        acceptance rate moves throughput only (recorded per session via
        ``telemetry.on_spec``)."""
        k = self.speculate
        draft = self.drafter.propose_batch(k)
        t0 = time.perf_counter()
        toks, m, last, self.state, self.slot_keys = self._spec(
            self.params, self.state, self.last_token, jnp.asarray(draft),
            self.slot_keys, jnp.asarray(self.temps),
            jnp.asarray(run_mask), eos=jnp.asarray(self.eos))
        self.last_token = last
        host_toks = np.asarray(toks)         # the ONE host sync per round
        host_m = np.asarray(m)
        compiled = tag_compiled(self._warm, "spec_chunk")
        self.stats.append(StepStats(
            "spec_chunk", time.perf_counter() - t0,
            tokens=int(host_m[np.nonzero(run_mask)[0]].sum()),
            compiled=compiled, forward_tokens=k + 1))
        for slot in np.nonzero(run_mask)[0]:
            self._slot_chunks[slot] += 1
            sess = self.sessions[slot]
            acc = host_toks[slot, :host_m[slot]].tolist()
            before = len(sess.tokens)
            sess.deliver(acc)
            if self.drafter is not None and not sess.done:
                # the drafter tracks STATE CONTENT (committed tokens),
                # even past the delivery budget clip
                self.drafter.observe(slot, acc)
            if self.telemetry is not None:
                self.telemetry.on_tokens(sess, len(sess.tokens) - before,
                                         self.clock, compiled)
                self.telemetry.on_spec(sess, drafted=k,
                                       accepted=int(host_m[slot]) - 1)
            if sess.done:
                self._release(slot)
                if self.telemetry is not None:
                    self.telemetry.on_retire(sess, self.clock)
        self._tick_telemetry()
        return True

    def run(self) -> None:
        """Drive chunks until every submitted session has completed.

        Raises instead of spinning: if nothing could be admitted and
        nothing could decode (every active slot fork-paused, or no
        active slot at all) while work remains, no future chunk can
        ever free pages or slots — busy-looping would never terminate."""
        while True:
            if self.step():
                continue
            if not self.pending and not self.active.any():
                return
            head = self.pending[0] if self.pending else None
            need = self._pages_needed(head) if head and self._paged else 0
            pool = self.layout.pool_pages if self._paged else 0
            raise RuntimeError(
                f"scheduler stuck: {len(self.pending)} pending and "
                f"{self.n_active} fork-paused session(s) with nothing able "
                f"to decode or free resources (head needs {need} pages; "
                f"free {len(self.free_pages)}/{pool}) — the pool/slot "
                f"accounting cannot make progress")

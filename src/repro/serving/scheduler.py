"""Slot-based continuous-batching scheduler for streaming inference.

The scheduler owns one fixed-shape multi-slot ``DecodeState`` and admits
/ evicts :class:`~repro.serving.session.Session` objects mid-flight:

* **admit** — a free slot is filled by ``prefill_into_slot``: the
  session's prompt (its own length; compiled once per distinct length)
  is prefilled as a single dense row and scattered into the batched
  state.  Running slots are untouched, so a new request joins a
  half-decoded batch without disturbing it.  Under a **paged** cache
  layout the scheduler is also the page allocator: admission assigns
  just enough pool pages to cover the session's prompt + budget (the
  page table is host-side slot surgery), and a session whose pages
  aren't available yet simply waits in the queue — so a pool sized
  well below ``slots * max_len`` serves short sessions at a fraction
  of the dense footprint.
* **decode** — all slots advance together in chunks of ``chunk_size``
  tokens.  A chunk is ONE jitted ``lax.scan`` over the fused step: the
  TConst W_og resync fires on device through the compacted row-wise
  ``sync_rows`` (each boundary row synced at batch size 1 — slots do
  not pay for each other's misses), so a chunk performs zero per-token
  host round-trips (one device->host transfer per chunk, for the
  sampled ids).  A slot that samples its session's EOS id sets the
  on-device ``done`` flag and is frozen for the rest of the chunk.
* **retire** — a session that exhausts its budget or hits EOS frees its
  slot at the chunk boundary (the slot is cleared so stale phase
  counters cannot re-trigger syncs; paged: its pages return to the
  free pool).

Chunk timings are recorded as ``StepStats(kind="chunk")`` entries; the
first entry includes the one-time jit compile of the chunked scan, so
aggregate with a median (or drop it) when reporting dispatch cost.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layouts as LT
from repro.models.api import DecodeAPI, decode_chunk, sample_tokens
from repro.serving.session import Session


class SlotScheduler:
    def __init__(self, decode: DecodeAPI, params: Any, slots: int,
                 max_len: int, chunk_size: int = 8, seed: int = 0):
        # accept a ModelAPI facade too (duck-typed .decode)
        if not isinstance(decode, DecodeAPI) and hasattr(decode, "decode"):
            decode = decode.decode
        if slots < 1:
            raise ValueError("scheduler needs at least one decode slot")
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        self.decode = decode
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_size = chunk_size

        self.state = decode.init_state(slots, max_len)
        self.layout = self.state.layout
        # prefilled rows are always dense; slot scatter goes through the
        # batched state's layout (paged: page-map surgery)
        dense_decode = dataclasses.replace(decode, layout=LT.DENSE_SPEC)
        self._empty_row = dense_decode.init_state(1, max_len)
        self._prefill_slot = jax.jit(decode.prefill_into_slot)
        self._chunk = jax.jit(functools.partial(decode_chunk, decode),
                              static_argnames=("n_steps",))
        self._clear = jax.jit(lambda st, slot, row: st.with_slot(slot, row))

        # paged layout: the scheduler owns page assignment.  Start from an
        # all-TRASH table (unique real-page ownership is the invariant the
        # pack/scatter relies on) with every pool page free.  Page
        # accounting only applies when the cache actually HAS paged
        # fields — for caches that are already O(1) (pure tconst) the
        # paged layout stores nothing in pages and admission must not
        # gate on the pool.
        self._paged = isinstance(self.layout, LT.PagedLayout) and \
            self.layout.pages_anything(self.state.kv)
        self.free_pages: List[int] = []
        self._slot_pages: List[List[int]] = [[] for _ in range(slots)]
        if self._paged:
            trash = jnp.full((slots, self.layout.pages_per_slot),
                             self.layout.trash, jnp.int32)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: trash})
            self.free_pages = list(range(self.layout.pool_pages))

        self.key = jax.random.PRNGKey(seed)
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.temps = np.zeros((slots,), np.float32)
        self.eos = np.full((slots,), -1, np.int32)
        self.active = np.zeros((slots,), bool)
        self.sessions: List[Optional[Session]] = [None] * slots
        self.pending: Deque[Session] = collections.deque()
        self.stats: List["StepStats"] = []

    # ------------------------------------------------------------------
    def _pages_needed(self, session: Session) -> int:
        need = len(session.prompt) + session.max_new_tokens + self.chunk_size
        return -(-need // self.layout.page)

    def submit(self, session: Session) -> Session:
        """Queue a session; it is admitted at the next chunk boundary."""
        # decode writes token ids into the slot's fixed (max_len,) buffer;
        # an overflowing write would be silently dropped by the scatter and
        # corrupt the next resync, so reject oversized requests up front
        # (chunk_size headroom: a session may overshoot its budget by up
        # to one chunk before it is retired at the chunk boundary).
        need = len(session.prompt) + session.max_new_tokens + self.chunk_size
        if need > self.max_len:
            raise ValueError(
                f"session {session.sid}: prompt {len(session.prompt)} + "
                f"max_new_tokens {session.max_new_tokens} (+ chunk "
                f"{self.chunk_size}) exceeds max_len {self.max_len}")
        if self._paged and \
                self._pages_needed(session) > self.layout.pool_pages:
            raise ValueError(
                f"session {session.sid}: needs {self._pages_needed(session)}"
                f" pages but the paged pool only has "
                f"{self.layout.pool_pages} — it could never be admitted")
        self.pending.append(session)
        return session

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def kv_bytes(self) -> int:
        return self.state.kv_bytes()

    # ------------------------------------------------------------------
    def _assign_pages(self, slot: int, n_pages: int) -> None:
        pages = [self.free_pages.pop() for _ in range(n_pages)]
        self._slot_pages[slot] = pages
        row = np.full((self.layout.pages_per_slot,), self.layout.trash,
                      np.int32)
        row[:n_pages] = pages
        pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(
            jnp.asarray(row))
        self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})

    def _admit_pending(self) -> None:
        free = [i for i in range(self.slots) if not self.active[i]]
        while self.pending and free:
            sess = self.pending[0]
            if self._paged and \
                    self._pages_needed(sess) > len(self.free_pages):
                break                  # wait for running sessions to retire
            self.pending.popleft()
            slot = free.pop(0)
            if self._paged:
                self._assign_pages(slot, self._pages_needed(sess))
            logits, self.state = self._prefill_slot(
                self.params, self.state, np.int32(slot),
                jnp.asarray(sess.prompt), extras=sess.extras)
            self.key, sub = jax.random.split(self.key)
            t0 = sample_tokens(logits[None],
                               jnp.full((1,), sess.temperature), sub)[0]
            self.last_token = self.last_token.at[slot].set(t0)
            sess.slot = slot
            self.sessions[slot] = sess
            self.active[slot] = True
            self.temps[slot] = sess.temperature
            self.eos[slot] = -1 if sess.eos_id is None else sess.eos_id
            sess.deliver([int(t0)])          # first token: prefill logits
            if sess.done:
                self._release(slot)
                free.insert(0, slot)

    def _release(self, slot: int) -> None:
        self.sessions[slot] = None
        self.active[slot] = False
        self.temps[slot] = 0.0
        self.eos[slot] = -1
        # clear the slot so stale phase counters can't keep firing the
        # on-device resync for an empty row (paged: zeros are written
        # through the slot's still-assigned pages)
        self.state = self._clear(self.state, np.int32(slot),
                                 self._empty_row)
        if self._paged:
            # recycle from the host-side assignment record — no device
            # read-back on the eviction path
            self.free_pages.extend(self._slot_pages[slot])
            self._slot_pages[slot] = []
            trash_row = jnp.full((self.layout.pages_per_slot,),
                                 self.layout.trash, jnp.int32)
            pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(trash_row)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})
        self.last_token = self.last_token.at[slot].set(0)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit pending sessions, then decode ONE chunk for all active
        slots (a single dispatch).  Returns False when idle."""
        from repro.serving.engine import StepStats
        self._admit_pending()
        if not self.active.any():
            return False
        t0 = time.perf_counter()
        toks, self.state, self.key = self._chunk(
            self.params, self.state, self.last_token, self.key,
            jnp.asarray(self.temps), jnp.asarray(self.active),
            n_steps=self.chunk_size, eos=jnp.asarray(self.eos))
        self.last_token = toks[:, -1]
        host_toks = np.asarray(toks)         # the ONE host sync per chunk
        self.stats.append(StepStats("chunk", time.perf_counter() - t0,
                                    tokens=self.chunk_size))
        for slot in np.nonzero(self.active)[0]:
            sess = self.sessions[slot]
            sess.deliver(host_toks[slot])
            if sess.done:
                self._release(slot)
        return True

    def run(self) -> None:
        """Drive chunks until every submitted session has completed."""
        while True:
            if not self.step() and not self.pending:
                return

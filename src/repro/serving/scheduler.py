"""Slot-based continuous-batching scheduler for streaming inference.

The scheduler owns one fixed-shape multi-slot ``DecodeState`` and admits
/ evicts :class:`~repro.serving.session.Session` objects mid-flight:

* **admit** — a free slot is filled by ``prefill_into_slot``: the
  session's prompt (its own length; compiled once per distinct length)
  is prefilled as a single dense row and scattered into the batched
  state.  Running slots are untouched, so a new request joins a
  half-decoded batch without disturbing it.  Under a **paged** cache
  layout the scheduler is also the page allocator: admission assigns
  just enough pool pages to cover the session's prompt + budget (the
  page table is host-side slot surgery), and a session whose pages
  aren't available yet waits in the queue — later queued sessions that
  DO fit are admitted past it (bounded skip-ahead, so the head cannot
  be starved) — so a pool sized well below ``slots * max_len`` serves
  short sessions at a fraction of the dense footprint.
* **prefix sharing (copy-on-write)** — with ``prefix_sharing=True`` the
  scheduler keeps a host-side content-addressed map from page-aligned
  prompt-token chunks to resident pool pages, with per-page refcounts.
  A session whose prompt prefix matches resident pages MAPS them into
  its ``layout__page_table`` instead of re-allocating and re-writing
  them (the admission scatter is masked to the unshared tail), so S
  sessions sharing a system prompt store its KV once.  Pages are
  writable only at refcount 1: before a chunk in which a slot's
  periodic resync may fire (``DecodeAPI.sync_anticipated``), its shared
  pages are FORKED to fresh pool pages (device-side copy, table
  surgery) — token appends never target shared pages by construction
  (only pages wholly inside ``stable_prefix_len`` enter the map).
  ``_release`` decrements refcounts; a page returns to ``free_pages``
  (and leaves the map) only at refcount 0.
* **decode** — all slots advance together in chunks of ``chunk_size``
  tokens.  A chunk is ONE jitted ``lax.scan`` over the fused step: the
  TConst W_og resync fires on device through the compacted row-wise
  ``sync_rows`` (each boundary row synced at batch size 1 — slots do
  not pay for each other's misses), so a chunk performs zero per-token
  host round-trips (one device->host transfer per chunk, for the
  sampled ids).  A slot that samples its session's EOS id sets the
  on-device ``done`` flag and is frozen for the rest of the chunk.
* **retire** — a session that exhausts its budget or hits EOS frees its
  slot at the chunk boundary (the slot's page-table row is retargeted
  at TRASH before the clearing write, so clearing can never land on a
  page another slot still references; pages whose refcount hits 0
  return to the free pool).

Chunk timings are recorded as ``StepStats(kind="chunk")`` entries and
admissions as ``StepStats(kind="admit")`` in ``admit_stats``; entries
whose wall-clock includes a one-time jit compile carry
``compiled=True`` so aggregations (``benchmarks/bench_inference``)
can exclude them.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layouts as LT
from repro.models.api import DecodeAPI, decode_chunk, sample_tokens
from repro.serving.engine import StepStats, tag_compiled
from repro.serving.session import Session


class SlotScheduler:
    def __init__(self, decode: DecodeAPI, params: Any, slots: int,
                 max_len: int, chunk_size: int = 8, seed: int = 0,
                 prefix_sharing: bool = False,
                 max_head_skips: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        # accept a ModelAPI facade too (duck-typed .decode)
        if not isinstance(decode, DecodeAPI) and hasattr(decode, "decode"):
            decode = decode.decode
        if slots < 1:
            raise ValueError("scheduler needs at least one decode slot")
        if chunk_size < 1:
            raise ValueError("chunk_size must be positive")
        self.decode = decode
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.chunk_size = chunk_size
        # chunked KV-conditioned admission: default rides on the decode
        # protocol (build_decode(prefill_chunk=...)); None = one-shot
        # full-prompt prefill (one compile per distinct prompt length)
        if prefill_chunk is None:
            prefill_chunk = getattr(decode, "prefill_chunk", None)
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be positive (or None "
                             "for one-shot admission)")
        self.prefill_chunk = prefill_chunk

        self.state = decode.init_state(slots, max_len)
        self.layout = self.state.layout
        # prefilled rows are always dense; slot scatter goes through the
        # batched state's layout (paged: page-map surgery)
        dense_decode = dataclasses.replace(decode, layout=LT.DENSE_SPEC)
        self._empty_row = dense_decode.init_state(1, max_len)
        self._prefill_slot = jax.jit(decode.prefill_into_slot)
        self._chunk = jax.jit(functools.partial(decode_chunk, decode),
                              static_argnames=("n_steps",))
        self._clear = jax.jit(lambda st, slot, row: st.with_slot(slot, row))

        # paged layout: the scheduler owns page assignment.  Start from an
        # all-TRASH table (a real page is writable iff its refcount is 1 —
        # the invariant the pack/scatter and the CoW fork rely on) with
        # every pool page free.  Page accounting only applies when the
        # cache actually HAS paged fields — for caches that are already
        # O(1) (pure tconst) the paged layout stores nothing in pages and
        # admission must not gate on the pool.
        self._paged = isinstance(self.layout, LT.PagedLayout) and \
            self.layout.pages_anything(self.state.kv)
        self.free_pages: List[int] = []
        self._slot_pages: List[List[int]] = [[] for _ in range(slots)]
        self._page_ref = np.zeros((0,), np.int32)
        if self._paged:
            trash = jnp.full((slots, self.layout.pages_per_slot),
                             self.layout.trash, jnp.int32)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: trash})
            self.free_pages = list(range(self.layout.pool_pages))
            self._page_ref = np.zeros((self.layout.pool_pages,), np.int32)
            self._fork = jax.jit(lambda st, src, dst: dataclasses.replace(
                st, kv=self.layout.fork_pages(st.kv, src, dst)))
        if self.prefill_chunk is not None and self._paged and \
                self.prefill_chunk % self.layout.page != 0:
            raise ValueError(
                f"prefill_chunk {self.prefill_chunk} must be a multiple "
                f"of the page size {self.layout.page} — chunk-granular "
                f"page writes cover whole pages")

        self.prefix_sharing = bool(prefix_sharing) and self._paged
        self._prefix_map: Dict[bytes, int] = {}   # chunk-chain key -> page
        self._page_key: Dict[int, bytes] = {}     # page -> its map key
        # a resyncing model (tconst/tlin) eventually FORKS every page it
        # adopted, so a sharing admission must reserve that headroom up
        # front — otherwise admission could overcommit the pool into a
        # state where no slot can ever fork (LM families never fork)
        self._fork_reserve = self.prefix_sharing and bool(
            np.any(self.decode.sync_anticipated(self.state, 1 << 30)))
        self._key_cache: Dict[int, List[bytes]] = {}   # sid -> chunk keys
        # bounded skip-ahead: how many sessions may be admitted past a
        # page-blocked queue head before admission stops overtaking it
        # (freed pages then necessarily reach the head: eventual FIFO)
        self.max_head_skips = 4 * slots if max_head_skips is None \
            else max_head_skips
        self._head_skips = 0

        self.key = jax.random.PRNGKey(seed)
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.temps = np.zeros((slots,), np.float32)
        self.eos = np.full((slots,), -1, np.int32)
        self.active = np.zeros((slots,), bool)
        self.sessions: List[Optional[Session]] = [None] * slots
        self.pending: Deque[Session] = collections.deque()
        self.stats: List[StepStats] = []
        self.admit_stats: List[StepStats] = []
        self._warm: set = set()       # (kind, signature) -> compiled tag

    # ------------------------------------------------------------------
    def _pages_needed(self, session: Session) -> int:
        need = len(session.prompt) + session.max_new_tokens + self.chunk_size
        return -(-need // self.layout.page)

    def submit(self, session: Session) -> Session:
        """Queue a session; it is admitted at the next chunk boundary."""
        # decode writes token ids into the slot's fixed (max_len,) buffer;
        # an overflowing write would be silently dropped by the scatter and
        # corrupt the next resync, so reject oversized requests up front
        # (chunk_size headroom: a session may overshoot its budget by up
        # to one chunk before it is retired at the chunk boundary).
        need = len(session.prompt) + session.max_new_tokens + self.chunk_size
        if need > self.max_len:
            raise ValueError(
                f"session {session.sid}: prompt {len(session.prompt)} + "
                f"max_new_tokens {session.max_new_tokens} (+ chunk "
                f"{self.chunk_size}) exceeds max_len {self.max_len}")
        # total-pool capacity check: a session needing more pages than the
        # POOL holds would pass a max_len-only check but could never be
        # admitted, leaving run() to spin on it forever
        if self._paged and \
                self._pages_needed(session) > self.layout.pool_pages:
            raise ValueError(
                f"session {session.sid}: needs {self._pages_needed(session)}"
                f" pages but the paged pool only has "
                f"{self.layout.pool_pages} — it could never be admitted")
        self.pending.append(session)
        return session

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def kv_bytes(self) -> int:
        return self.state.kv_bytes()

    def assigned_kv_bytes(self) -> int:
        """KV bytes the live page tables reference — a prefix-shared
        page is counted once (see ``DecodeState.assigned_kv_bytes``)."""
        return self.state.assigned_kv_bytes()

    def page_refcounts(self) -> np.ndarray:
        """Host-side per-page refcounts (copy); all zeros when idle."""
        return self._page_ref.copy()

    # ------------------------------------------------------------------
    # prefix sharing: content-addressed page map + refcounts
    # ------------------------------------------------------------------
    def _chunk_keys(self, session: Session) -> List[bytes]:
        """Rolling content-addressed keys for the page-aligned prompt
        chunks inside the session's stable prefix.  Key i covers
        ``prompt[:(i+1)*page]`` — KV content at a position is a causal
        function of ALL preceding tokens — salted with a digest of the
        per-request extras (encoder memory / vision inputs feed the
        same KV, so sessions with different extras must never match)."""
        cached = self._key_cache.get(session.sid)
        if cached is not None:
            return cached
        page = self.layout.page
        stable = self.decode.stable_prefix_len(len(session.prompt))
        n = min(stable, len(session.prompt)) // page
        h = hashlib.sha1()
        if session.extras:
            for name in sorted(session.extras):
                h.update(name.encode())
                h.update(np.asarray(session.extras[name]).tobytes())
        prompt = np.ascontiguousarray(session.prompt, np.int32)
        keys = []
        for i in range(n):
            h.update(prompt[i * page:(i + 1) * page].tobytes())
            keys.append(h.copy().digest())
        # prompt/extras are immutable after submit: memoize so a blocked
        # queue doesn't re-hash megabyte extras once per chunk
        self._key_cache[session.sid] = keys
        return keys

    def _register(self, key: bytes, page: int) -> None:
        self._prefix_map[key] = page
        self._page_key[page] = key

    def _unregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            self._prefix_map.pop(key, None)

    def _set_table_row(self, slot: int, pages: List[int]) -> None:
        self._slot_pages[slot] = list(pages)
        row = np.full((self.layout.pages_per_slot,), self.layout.trash,
                      np.int32)
        row[:len(pages)] = pages
        pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(
            jnp.asarray(row))
        self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _admission_plan(self, session: Session) -> Optional[Dict[str, Any]]:
        """The pages this admission would take, or None if it must wait
        for the free pool.  With prefix sharing, resident pages matching
        the session's prompt-prefix chunks are adopted instead of drawn
        from the free pool."""
        if not self._paged:
            return {"total": 0, "adopted": [], "keys": []}
        total = self._pages_needed(session)
        keys = self._chunk_keys(session) if self.prefix_sharing else []
        adopted: List[int] = []
        for key in keys:
            page = self._prefix_map.get(key)
            if page is None:
                break
            adopted.append(page)
        # resyncing models: adopted pages will be forked before this
        # slot's first resync, so their copies count against the pool now
        reserve = len(adopted) if self._fork_reserve else 0
        if total - len(adopted) + reserve > len(self.free_pages):
            return None                # wait for running sessions to retire
        return {"total": total, "adopted": adopted, "keys": keys}

    def _admit(self, session: Session, slot: int,
               plan: Dict[str, Any]) -> None:
        mask = None
        if self._paged:
            n_adopt = len(plan["adopted"])
            fresh = [self.free_pages.pop()
                     for _ in range(plan["total"] - n_adopt)]
            pages = list(plan["adopted"]) + fresh
            for p in plan["adopted"]:
                self._page_ref[p] += 1
            for p in fresh:
                self._page_ref[p] = 1
            if self.prefix_sharing:
                # register this prompt's freshly written stable pages so
                # later sessions can adopt them (adopted ones already are)
                for i, key in enumerate(plan["keys"]):
                    if key not in self._prefix_map:
                        self._register(key, pages[i])
                if n_adopt:
                    # tail-only admission write: adopted pages hold the
                    # identical (content-addressed) KV already — CoW says
                    # never write a page with refcount > 1
                    host_mask = np.ones((self.layout.pages_per_slot,), bool)
                    host_mask[:n_adopt] = False
                    mask = jnp.asarray(host_mask)
            self._set_table_row(slot, pages)
        resident = len(plan["adopted"]) * self.layout.page \
            if self._paged else 0
        chunked = self.prefill_chunk is not None and \
            self.decode.supports_chunked_prefill(session.extras) and \
            self.decode.chunked_prefill_fits(
                len(session.prompt), resident, self.prefill_chunk,
                self.max_len)
        extras_sig = tuple(sorted(
            (k, tuple(np.shape(v))) for k, v in (session.extras or {}).items()))
        t0 = time.perf_counter()
        if chunked:
            # KV-conditioned chunked admission: forward compute covers
            # only the unshared tail (adopted pages are attended, not
            # recomputed... except the one chunk the logits need), and
            # every dispatch has a fixed shape — the compile signature
            # is the BUCKET (chunk size x variants), not the prompt
            # length, so K distinct lengths share one compiled set.
            logits, self.state, info = self.decode.prefill_into_slot_chunked(
                self.params, self.state, np.int32(slot), session.prompt,
                extras=session.extras, page_write_mask=mask,
                resident_len=resident, chunk=self.prefill_chunk)
            fwd = info["forward_tokens"]
            sig = ("chunked", self.prefill_chunk, resident > 0,
                   mask is not None, extras_sig)
        else:
            logits, self.state = self._prefill_slot(
                self.params, self.state, np.int32(slot),
                jnp.asarray(session.prompt), extras=session.extras,
                page_write_mask=mask)
            fwd = len(session.prompt)
            # the one-shot prefill retraces on any shape change: prompt
            # length, mask presence, AND extras shapes
            sig = (len(session.prompt), mask is not None, extras_sig)
        logits = jax.block_until_ready(logits)
        self._key_cache.pop(session.sid, None)
        self.admit_stats.append(StepStats(
            "admit", time.perf_counter() - t0, tokens=len(session.prompt),
            compiled=tag_compiled(self._warm, "admit", sig),
            forward_tokens=fwd))
        self.key, sub = jax.random.split(self.key)
        t0k = sample_tokens(logits[None],
                            jnp.full((1,), session.temperature), sub)[0]
        self.last_token = self.last_token.at[slot].set(t0k)
        session.slot = slot
        self.sessions[slot] = session
        self.active[slot] = True
        self.temps[slot] = session.temperature
        self.eos[slot] = -1 if session.eos_id is None else session.eos_id
        session.deliver([int(t0k)])          # first token: prefill logits

    def admit_pending(self) -> bool:
        """Admit as many pending sessions as free slots/pages allow.
        FIFO first; when the HEAD is waiting on pool pages, later
        sessions that fit are admitted past it — but at most
        ``max_head_skips`` consecutive overtakes, so freed pages
        eventually reach the head (no starvation, no head-of-line
        blocking).  Returns True if any session was admitted."""
        free = [i for i in range(self.slots) if not self.active[i]]
        admitted = False
        idx = 0
        while free and idx < len(self.pending):
            session = self.pending[idx]
            plan = self._admission_plan(session)
            if plan is None:
                if idx == 0 and self._head_skips >= self.max_head_skips:
                    break          # skip budget spent: wait for the head
                idx += 1
                continue
            del self.pending[idx]
            self._head_skips = self._head_skips + 1 if idx else 0
            slot = free.pop(0)
            self._admit(session, slot, plan)
            admitted = True
            if session.done:
                self._release(slot)
                free.insert(0, slot)
        if not self.pending:
            self._head_skips = 0
        return admitted

    # ------------------------------------------------------------------
    # copy-on-write forking (chunk boundary)
    # ------------------------------------------------------------------
    def _cow_before_chunk(self) -> np.ndarray:
        """A page is writable iff refcount == 1.  The only device-side
        writes that can target resident prefix pages are the periodic
        resync's KV rebuild (token appends land beyond the stable
        prefix by construction), so any active slot whose resync may
        fire within the next chunk is made page-private NOW.  A slot
        that cannot fork (no free pages for the copies) is PAUSED for
        this chunk — masked out of the dispatch, frozen bit-identically
        — and retried once retiring sessions free pages.  Admission's
        fork reserve is checked per-admission against the instantaneous
        free pool (commitments are not tracked across slots — e.g. a
        slot's pages become shared only when a LATER session adopts
        them), so pausing is the backstop that keeps in-flight sessions
        alive instead of crashing them.  Returns the (B,) mask of slots
        that actually decode this chunk."""
        run_mask = self.active.copy()
        anticipated = self.decode.sync_anticipated(self.state,
                                                   self.chunk_size)
        for slot in np.nonzero(self.active)[0]:
            if anticipated[slot] and not self._make_slot_private(int(slot)):
                run_mask[slot] = False
        return run_mask

    def _make_slot_private(self, slot: int) -> bool:
        """Fork the slot's shared pages to fresh ones; True on success,
        False when the free pool cannot back the copies (caller pauses
        the slot — forking later is always still correct)."""
        pages = self._slot_pages[slot]
        shared = [j for j, p in enumerate(pages) if self._page_ref[p] > 1]
        if len(shared) > len(self.free_pages):
            return False
        for p in pages:
            if self._page_ref[p] == 1:
                # sole owner about to rewrite the page: its content may
                # stop matching the registered token prefix — retract it
                self._unregister(p)
        if not shared:
            return True
        fresh = [self.free_pages.pop() for _ in shared]
        pps = self.layout.pages_per_slot
        src = np.full((pps,), self.layout.trash, np.int32)
        dst = np.full((pps,), self.layout.trash, np.int32)
        for k, (j, p_new) in enumerate(zip(shared, fresh)):
            src[k], dst[k] = pages[j], p_new
        self.state = self._fork(self.state, jnp.asarray(src),
                                jnp.asarray(dst))
        for j, p_new in zip(shared, fresh):
            self._page_ref[pages[j]] -= 1
            self._page_ref[p_new] = 1
            pages[j] = p_new
        self._set_table_row(slot, pages)
        return True

    # ------------------------------------------------------------------
    def _release(self, slot: int) -> None:
        self.sessions[slot] = None
        self.active[slot] = False
        self.temps[slot] = 0.0
        self.eos[slot] = -1
        if self._paged:
            # retarget the table row at TRASH before the clearing write
            # below, so clearing zeros can never land on a page another
            # slot still references (prefix sharing); then drop refs —
            # a page is recycled (and leaves the prefix map) only at 0
            trash_row = jnp.full((self.layout.pages_per_slot,),
                                 self.layout.trash, jnp.int32)
            pt = self.state.bookkeeping[LT.PAGE_TABLE].at[slot].set(trash_row)
            self.state = self.state.with_bookkeeping(**{LT.PAGE_TABLE: pt})
            for p in self._slot_pages[slot]:
                self._page_ref[p] -= 1
                if self._page_ref[p] == 0:
                    self._unregister(p)
                    self.free_pages.append(p)
            self._slot_pages[slot] = []
        # clear the slot so stale phase counters can't keep firing the
        # on-device resync for an empty row (paged: the writes land on
        # the trash page — the slot no longer owns real pages)
        self.state = self._clear(self.state, np.int32(slot),
                                 self._empty_row)
        self.last_token = self.last_token.at[slot].set(0)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit pending sessions, then decode ONE chunk for the active
        slots (a single dispatch; slots paused for copy-on-write fork
        headroom are masked out, frozen bit-identically).  Returns False
        when no progress was made — nothing admitted and nothing could
        decode."""
        admitted = self.admit_pending()
        if not self.active.any():
            return admitted
        run_mask = self._cow_before_chunk() if self.prefix_sharing \
            else self.active
        if not run_mask.any():
            return admitted            # every active slot fork-paused
        t0 = time.perf_counter()
        toks, self.state, self.key = self._chunk(
            self.params, self.state, self.last_token, self.key,
            jnp.asarray(self.temps), jnp.asarray(run_mask),
            n_steps=self.chunk_size, eos=jnp.asarray(self.eos))
        self.last_token = toks[:, -1]
        host_toks = np.asarray(toks)         # the ONE host sync per chunk
        self.stats.append(StepStats(
            "chunk", time.perf_counter() - t0, tokens=self.chunk_size,
            compiled=tag_compiled(self._warm, "chunk")))
        for slot in np.nonzero(run_mask)[0]:
            sess = self.sessions[slot]
            sess.deliver(host_toks[slot])
            if sess.done:
                self._release(slot)
        return True

    def run(self) -> None:
        """Drive chunks until every submitted session has completed.

        Raises instead of spinning: if nothing could be admitted and
        nothing could decode (every active slot fork-paused, or no
        active slot at all) while work remains, no future chunk can
        ever free pages or slots — busy-looping would never terminate."""
        while True:
            if self.step():
                continue
            if not self.pending and not self.active.any():
                return
            head = self.pending[0] if self.pending else None
            need = self._pages_needed(head) if head and self._paged else 0
            pool = self.layout.pool_pages if self._paged else 0
            raise RuntimeError(
                f"scheduler stuck: {len(self.pending)} pending and "
                f"{self.n_active} fork-paused session(s) with nothing able "
                f"to decode or free resources (head needs {need} pages; "
                f"free {len(self.free_pages)}/{pool}) — the pool/slot "
                f"accounting cannot make progress")

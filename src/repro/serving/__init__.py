from repro.models.layouts import LayoutSpec  # noqa: F401
from repro.serving import engine  # noqa: F401
from repro.serving.engine import Engine, StepStats  # noqa: F401
from repro.serving.scheduler import SlotScheduler  # noqa: F401
from repro.serving.session import Session  # noqa: F401

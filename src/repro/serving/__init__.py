from repro.models.layouts import LayoutSpec  # noqa: F401
from repro.serving import engine  # noqa: F401
from repro.serving.engine import Engine, StepStats  # noqa: F401
from repro.serving.metrics import ServingTelemetry  # noqa: F401
from repro.serving.policy import (DeadlineCostPolicy, FifoPolicy,  # noqa: F401
                                  SchedulingPolicy, get_policy)
from repro.serving.scheduler import SlotScheduler  # noqa: F401
from repro.serving.session import Session  # noqa: F401
from repro.serving.workload import (Arrival, WorkloadSpec,  # noqa: F401
                                    generate_workload)

"""Seeded, deterministic serving-workload generator.

The scheduler benches need *realistic traffic*, not a handful of
hand-rolled sessions: arrival bursts that oversubscribe the pool,
prompt/output-length mixes, a population of requests sharing a system
prompt (exercising the PR-4 prefix-sharing pages), and verbatim repeats
of earlier prompts (exercising the PR-6 O(1) tconst re-admission).
This module turns a :class:`WorkloadSpec` plus one integer seed into a
reproducible list of :class:`Arrival` events — the SAME spec and seed
always produce the same prompts, lengths, arrival chunks, SLO targets
and per-session sampling seeds, so two scheduler runs (e.g. the FIFO
baseline vs the deadline policy in ``benchmarks/bench_serving.py``) can
replay one trace and be compared session-by-session.

Time is denominated in scheduler *chunks* (one ``SlotScheduler.step``
call = one tick): ``Arrival.at_chunk`` is when the session is submitted
and every SLO target (``slo_ttft_chunks`` / ``slo_itl_chunks``) counts
the same clock, which keeps the workload deterministic across hosts —
wall-clock telemetry rides on top in ``repro.serving.metrics``.

Two arrival processes:

* ``poisson`` — i.i.d. exponential inter-arrival gaps with mean
  ``1 / rate`` chunks (classic open-loop traffic).
* ``bursty`` — an on/off process: burst starts are Poisson with mean
  ``burst_every`` chunks apart and each burst drops
  ``1 + Poisson(burst_size - 1)`` sessions on the same chunk — the
  oversubscription pattern the tier-store spill path exists for.

Length mixes are weighted uniform components ``(weight, lo, hi)`` —
e.g. a 70/30 chat/document mix.  A ``shared_frac`` slice of sessions
prefixes one of ``n_prefixes`` common system prompts (page-align
``prefix_len`` to share whole pages); a ``repeat_frac`` slice re-issues
a previously generated prompt verbatim.  An ``slo_frac`` slice carries
a TTFT deadline and elevated priority (the rest ride best-effort).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.session import Session

Mix = Sequence[Tuple[float, int, int]]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one traffic trace (see module doc)."""

    n_sessions: int
    vocab: int
    # arrival process ------------------------------------------------------
    arrival: str = "poisson"             # "poisson" | "bursty"
    rate: float = 0.5                    # poisson: mean arrivals per chunk
    burst_size: int = 6                  # bursty: mean sessions per burst
    burst_every: float = 24.0            # bursty: mean chunks between bursts
    # request shape --------------------------------------------------------
    prompt_mix: Mix = ((0.7, 8, 24), (0.3, 32, 56))
    output_mix: Mix = ((0.8, 8, 16), (0.2, 20, 32))
    # populations ----------------------------------------------------------
    shared_frac: float = 0.0             # share one of n_prefixes prefixes
    n_prefixes: int = 2
    prefix_len: int = 16                 # page-align to share whole pages
    repeat_frac: float = 0.0             # verbatim re-issue of a past prompt
    # SLOs / priority ------------------------------------------------------
    slo_frac: float = 0.5                # fraction carrying a TTFT SLO
    slo_ttft_chunks: int = 8
    slo_itl_chunks: int = 0              # 0 = no inter-token SLO
    slo_priority: int = 1                # priority for the SLO slice
    temperature: float = 0.0

    def __post_init__(self):
        if self.n_sessions < 1:
            raise ValueError("n_sessions must be >= 1")
        if self.arrival not in ("poisson", "bursty"):
            raise ValueError(f"unknown arrival process {self.arrival!r} "
                             f"(poisson | bursty)")
        if self.arrival == "poisson" and self.rate <= 0:
            raise ValueError("poisson arrivals need rate > 0")
        if self.arrival == "bursty" and (self.burst_size < 1 or
                                         self.burst_every <= 0):
            raise ValueError("bursty arrivals need burst_size >= 1 and "
                             "burst_every > 0")
        for frac in (self.shared_frac, self.repeat_frac, self.slo_frac):
            if not 0.0 <= frac <= 1.0:
                raise ValueError("population fractions must be in [0, 1]")
        for mix in (self.prompt_mix, self.output_mix):
            if not mix or any(w <= 0 or lo < 1 or hi < lo
                              for w, lo, hi in mix):
                raise ValueError(f"malformed length mix {mix!r}")


@dataclasses.dataclass
class Arrival:
    """One workload event: submit ``session`` at chunk ``at_chunk``."""

    at_chunk: int
    session: Session


def _sample_mix(rng: np.random.RandomState, mix: Mix) -> int:
    w = np.asarray([m[0] for m in mix], np.float64)
    i = int(rng.choice(len(mix), p=w / w.sum()))
    return int(rng.randint(mix[i][1], mix[i][2] + 1))


def _arrival_chunks(rng: np.random.RandomState,
                    spec: WorkloadSpec) -> np.ndarray:
    if spec.arrival == "poisson":
        gaps = rng.exponential(1.0 / spec.rate, size=spec.n_sessions)
        return np.floor(np.cumsum(gaps)).astype(np.int64)
    # bursty: Poisson burst starts, Poisson(+1) burst sizes
    chunks: List[int] = []
    t = 0.0
    while len(chunks) < spec.n_sessions:
        t += rng.exponential(spec.burst_every)
        size = 1 + rng.poisson(max(spec.burst_size - 1, 0))
        chunks.extend([int(t)] * size)
    return np.asarray(chunks[: spec.n_sessions], np.int64)


def generate_workload(spec: WorkloadSpec, seed: int,
                      max_prompt_len: Optional[int] = None
                      ) -> List[Arrival]:
    """Generate the trace: a list of :class:`Arrival` sorted by
    ``at_chunk``.  Deterministic in ``(spec, seed)`` — session ids are
    process-global, so cross-run identity is by trace POSITION, and each
    session carries its own ``seed`` so its sampled stream is a pure
    function of the trace, not of slot placement or policy (see
    ``Session.seed``).  ``max_prompt_len`` optionally clips prompts (the
    caller knows its ``max_len`` budget)."""
    rng = np.random.RandomState(seed)
    arrivals = _arrival_chunks(rng, spec)
    prefixes = [rng.randint(1, spec.vocab, size=spec.prefix_len)
                .astype(np.int32) for _ in range(spec.n_prefixes)]
    out: List[Arrival] = []
    history: List[np.ndarray] = []
    for i in range(spec.n_sessions):
        u = rng.rand()
        if history and u < spec.repeat_frac:
            prompt = history[int(rng.randint(len(history)))].copy()
        else:
            n = _sample_mix(rng, spec.prompt_mix)
            if u < spec.repeat_frac + spec.shared_frac:
                head = prefixes[int(rng.randint(spec.n_prefixes))]
                tail = rng.randint(1, spec.vocab, size=n).astype(np.int32)
                prompt = np.concatenate([head, tail])
            else:
                prompt = rng.randint(1, spec.vocab,
                                     size=max(n, 1)).astype(np.int32)
        if max_prompt_len is not None:
            prompt = prompt[:max_prompt_len]
        history.append(prompt)
        tight = rng.rand() < spec.slo_frac
        out.append(Arrival(int(arrivals[i]), Session(
            prompt,
            max_new_tokens=_sample_mix(rng, spec.output_mix),
            temperature=spec.temperature,
            seed=int(rng.randint(1 << 31)),
            priority=spec.slo_priority if tight else 0,
            slo_ttft_chunks=spec.slo_ttft_chunks if tight else None,
            slo_itl_chunks=(spec.slo_itl_chunks or None) if tight
            else None)))
    out.sort(key=lambda a: a.at_chunk)
    return out

"""Pluggable scheduling policies for the slot scheduler.

The :class:`~repro.serving.scheduler.SlotScheduler` owns the *mechanism*
of serving — slot surgery, page accounting, spill/restore, the
starvation-free overtake budget — and delegates three *decisions* to a
:class:`SchedulingPolicy`:

* **admission order** (``order_pending``) — which pending session to try
  first when slots/pages free up.  The scheduler still enforces FIFO
  fairness underneath: every admission past the oldest blocked session
  (cold or resume-sourced) consumes one unit of its bounded overtake
  budget, and a spent budget forces strict arrival order until that
  session admits — so no policy can starve a request, only re-order
  within the budget.
* **admission control** (``defer_admission``) — whether to hold back an
  admissible session anyway, e.g. to keep pool pages free for a
  tighter-deadline request that does not fit yet.  Deferral is advisory:
  it is never applied to the protected queue head, so it cannot
  deadlock the scheduler.
* **preemption victims** (``select_victims``) — which ripe slots to
  spill when sessions wait.  The scheduler reports a per-slot
  ``spill_cost`` (estimated snapshot bytes + re-admission cost) so a
  policy can prefer cheap victims: a tconst slot's physical KV is O(1)
  and its admission is a pure function of the prompt
  (``DecodeAPI.admission_key``), so spilling it is nearly free, while a
  long-resident dense-LM slot pays O(tokens) bytes both ways.

Two policies ship:

* :class:`FifoPolicy` — the baseline: arrival order with the bounded
  skip-ahead, ripe-longest-resident-first preemption (exactly the
  pre-policy scheduler behaviour).
* :class:`DeadlineCostPolicy` — SLO-aware: admissions ordered by TTFT
  deadline slack then priority, cost-aware victim selection, and
  pool-pressure admission control that defers slack-rich sessions when
  a tighter-deadline session is blocked on pages.

Every hook is a pure function of host-side scheduler state — policies
never touch device arrays, so switching policies can never change a
session's token stream (asserted per-session by
``benchmarks/bench_serving.py``).
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING, List

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session


class SchedulingPolicy:
    """Decision seam consumed by ``SlotScheduler`` (see module doc)."""

    name = "base"

    def order_pending(self, pending: List["Session"],
                      sched: "SlotScheduler") -> List["Session"]:
        """Return the pending sessions in the order admission should try
        them.  Must be a permutation of ``pending`` (the scheduler keeps
        the arrival-order queue itself — this is only the try order)."""
        return list(pending)

    def defer_admission(self, sched: "SlotScheduler", session: "Session",
                        plan: dict) -> bool:
        """True to hold back an admissible ``session`` this round (pool-
        pressure admission control).  Never consulted for the protected
        arrival-order head, so deferral cannot starve or deadlock."""
        return False

    def select_victims(self, sched: "SlotScheduler", ripe: List[int],
                       n: int) -> List[int]:
        """Choose up to ``n`` slots to preempt-spill out of the ``ripe``
        candidates (slots that decoded >= ``preempt_chunks`` chunks this
        residency)."""
        return ripe[:n]


class FifoPolicy(SchedulingPolicy):
    """Baseline: FIFO admission with the scheduler's bounded skip-ahead,
    ripe-longest-resident-first preemption — the pre-policy behaviour,
    kept as an explicit object so benches can name it."""

    name = "fifo"

    def select_victims(self, sched: "SlotScheduler", ripe: List[int],
                       n: int) -> List[int]:
        return sorted(ripe, key=lambda s: -int(sched._slot_chunks[s]))[:n]


def ttft_slack(session: "Session", now: int) -> float:
    """Chunks until the session's TTFT deadline (negative = missed);
    sessions without a TTFT SLO have infinite slack."""
    if session.slo_ttft_chunks is None or session.submit_clock is None:
        return math.inf
    return (session.submit_clock + session.slo_ttft_chunks) - now


class DeadlineCostPolicy(SchedulingPolicy):
    """Deadline- and cost-aware scheduling.

    * Admission tries pending sessions by ``(TTFT slack, -priority)``
      (stable, so equal-urgency sessions keep arrival order).
    * ``defer_admission`` holds back a session with ``defer_slack`` or
      more chunks of headroom when admitting it would leave the free
      pool too small for a *tighter*-slack session that is still
      blocked on pages.
    * Victims are the cheapest ripe slots by ``SlotScheduler.spill_cost``
      (snapshot bytes + re-admission bytes; a family whose admission is
      prompt-pure — tconst/tlin via ``admission_key`` — re-admits for
      free, so its cost is the tiny O(1) snapshot alone).  Slots whose
      session carries an inter-token SLO are spilled last: a spill gap
      is exactly what breaks that SLO.
    """

    name = "slo"

    def __init__(self, defer_slack: int = 4):
        if defer_slack < 0:
            raise ValueError("defer_slack must be >= 0 chunks")
        self.defer_slack = defer_slack

    def order_pending(self, pending, sched):
        now = sched.clock
        return sorted(pending, key=lambda s: (ttft_slack(s, now),
                                              -s.priority))

    def defer_admission(self, sched, session, plan):
        if not sched._paged or sched.n_active == 0:
            # deferral only manages POOL pressure, and deferring with
            # nothing active could stall the scheduler outright
            return False
        mine = ttft_slack(session, sched.clock)
        if mine < self.defer_slack:
            return False                       # too urgent to hold back
        adopted = len(plan.get("adopted", ()))
        free_after = len(sched.free_pages) - (plan.get("total", 0) - adopted)
        for other in sched.pending:
            if other is session:
                continue
            if ttft_slack(other, sched.clock) >= mine:
                continue
            need = sched._pages_needed(other)
            if need > len(sched.free_pages):   # other is page-blocked now
                if free_after < need:          # and we'd keep it blocked
                    return True
        return False

    def select_victims(self, sched, ripe, n):
        def cost(slot: int):
            session = sched.sessions[slot]
            itl_bound = session is not None and \
                session.slo_itl_chunks is not None
            return (itl_bound, sched.spill_cost(slot)["total"],
                    -int(sched._slot_chunks[slot]))
        return sorted(ripe, key=cost)[:n]


_POLICIES = {"fifo": FifoPolicy, "slo": DeadlineCostPolicy}


def get_policy(name: str) -> SchedulingPolicy:
    """Instantiate a policy by its registry name ("fifo" | "slo")."""
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {name!r} — "
                         f"choose from {sorted(_POLICIES)}") from None

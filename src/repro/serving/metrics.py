"""Per-session serving telemetry for the slot scheduler.

The engine-level ``StepStats`` answer "how fast is one decode chunk";
they say nothing about what a *request* experienced — how long it sat in
the queue, when its first token landed, how spill gaps stretched its
inter-token latency, whether it met its SLO.  This module adds that
request-level view: :class:`ServingTelemetry` is an observer the
scheduler drives through small ``on_*`` hooks, accumulating one
:class:`SessionRecord` per session plus a pool-occupancy timeline, and
summarising to p50/p99 on demand.

Two clocks are recorded side by side:

* **chunks** — the scheduler's deterministic tick (one ``step()`` = one
  chunk).  TTFT / queue-wait / inter-token gaps in chunk units are a
  pure function of the trace and policy, identical across hosts, and
  the basis for SLO attainment (SLO targets are expressed in chunks).
* **wall seconds** — measured TTFT per session, *excluding* sessions
  whose first chunk triggered a compile, following the PR-4
  ``StepStats.compiled`` convention: the scheduler reports whether each
  tick hit a fresh jit signature and ``on_tokens`` taints the TTFT of
  sessions whose first token rode a compiling dispatch.

Percentiles use the nearest-rank method on sorted samples — no
interpolation, so a p99 is always a latency some real session saw.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

__all__ = ["SessionRecord", "ServingTelemetry", "percentile"]


def percentile(samples: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (q in [0, 100]); None on no samples."""
    if not samples:
        return None
    xs = sorted(samples)
    rank = max(1, -(-len(xs) * q // 100))        # ceil(n * q / 100)
    return float(xs[min(int(rank), len(xs)) - 1])


@dataclasses.dataclass
class SessionRecord:
    """Everything telemetry knows about one session's lifetime."""

    sid: int
    prompt_len: int = 0
    max_new_tokens: int = 0
    priority: int = 0
    slo_ttft_chunks: Optional[int] = None
    slo_itl_chunks: Optional[int] = None
    submit_clock: Optional[int] = None
    first_admit_clock: Optional[int] = None      # first time in a slot
    ttft_chunks: Optional[int] = None            # submit -> first token
    ttft_seconds: Optional[float] = None         # wall; None if compile-hit
    ttft_compiled: bool = False                  # first token hit a compile
    itl_gaps_chunks: List[int] = dataclasses.field(default_factory=list)
    last_token_clock: Optional[int] = None
    tokens_out: int = 0
    spills: int = 0
    resumes: int = 0
    retire_clock: Optional[int] = None
    done: bool = False
    # speculative decoding: per-slot draft/accept totals (verify-exact,
    # so these are throughput figures — never stream content)
    spec_rounds: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def acceptance_rate(self) -> Optional[float]:
        """Accepted draft tokens / drafted tokens (the bonus token is
        excluded from both sides: it is sequential progress, not a
        speculation win).  None when the session never speculated."""
        if self.spec_drafted == 0:
            return None
        return self.spec_accepted / self.spec_drafted

    @property
    def queue_wait_chunks(self) -> Optional[int]:
        if self.submit_clock is None or self.first_admit_clock is None:
            return None
        return self.first_admit_clock - self.submit_clock

    @property
    def ttft_ok(self) -> Optional[bool]:
        """SLO attainment for TTFT; None when the session has no TTFT
        SLO or never produced a token (a starved SLO session counts as
        a miss, not a non-sample — see ``met`` below)."""
        if self.slo_ttft_chunks is None:
            return None
        if self.ttft_chunks is None:
            return False
        return self.ttft_chunks <= self.slo_ttft_chunks

    @property
    def itl_ok(self) -> Optional[bool]:
        if self.slo_itl_chunks is None:
            return None
        if not self.itl_gaps_chunks:
            return True                          # single-token stream
        return max(self.itl_gaps_chunks) <= self.slo_itl_chunks

    @property
    def slo_ok(self) -> Optional[bool]:
        """Joint attainment over whichever SLOs the session carries."""
        parts = [p for p in (self.ttft_ok, self.itl_ok) if p is not None]
        if not parts:
            return None
        return all(parts)


class ServingTelemetry:
    """Scheduler observer: one record per session + pool timeline.

    The scheduler calls the ``on_*`` hooks; nothing here touches device
    state, so telemetry can never perturb token streams.  All hooks are
    idempotent-by-sid where re-entry is possible (re-admission after a
    spill updates counters, not identity).
    """

    def __init__(self):
        self.records: Dict[int, SessionRecord] = {}
        self.occupancy: List[dict] = []          # one sample per tick
        self._submit_wall: Dict[int, float] = {}

    # -- lifecycle hooks (called by SlotScheduler) ------------------------
    def on_submit(self, session, clock: int) -> None:
        rec = self.records.get(session.sid)
        if rec is None:
            rec = SessionRecord(sid=session.sid)
            self.records[session.sid] = rec
            rec.prompt_len = len(session.prompt)
            rec.max_new_tokens = session.max_new_tokens
            rec.priority = session.priority
            rec.slo_ttft_chunks = session.slo_ttft_chunks
            rec.slo_itl_chunks = session.slo_itl_chunks
            rec.submit_clock = clock
            self._submit_wall[session.sid] = time.perf_counter()

    def on_admit(self, session, clock: int, source: str) -> None:
        rec = self.records[session.sid]
        if rec.first_admit_clock is None:
            rec.first_admit_clock = clock
        if source == "resume":
            rec.resumes += 1

    def on_spill(self, session, clock: int) -> None:
        self.records[session.sid].spills += 1

    def on_tokens(self, session, n_new: int, clock: int,
                  compiled: bool) -> None:
        """``n_new`` tokens delivered to ``session`` at tick ``clock``;
        ``compiled`` is whether the dispatch that produced them hit a
        fresh jit signature (taints wall-TTFT, PR-4 convention)."""
        if n_new <= 0:
            return
        rec = self.records[session.sid]
        if rec.tokens_out == 0:
            rec.ttft_chunks = (clock - rec.submit_clock
                               if rec.submit_clock is not None else None)
            rec.ttft_compiled = compiled
            wall = self._submit_wall.get(session.sid)
            rec.ttft_seconds = None if (compiled or wall is None) \
                else time.perf_counter() - wall
        elif rec.last_token_clock is not None:
            # n_new tokens landed this tick: the inter-tick gap belongs
            # to the first of them, the rest arrived within one chunk
            rec.itl_gaps_chunks.append(clock - rec.last_token_clock)
            rec.itl_gaps_chunks.extend([0] * (n_new - 1))
        rec.last_token_clock = clock
        rec.tokens_out += n_new

    def on_spec(self, session, drafted: int, accepted: int) -> None:
        """One speculative verify round for ``session``: ``drafted``
        tokens proposed, ``accepted`` of them verified-exact (bonus
        token excluded from both counts)."""
        rec = self.records[session.sid]
        rec.spec_rounds += 1
        rec.spec_drafted += drafted
        rec.spec_accepted += accepted

    def on_retire(self, session, clock: int) -> None:
        rec = self.records[session.sid]
        rec.retire_clock = clock
        rec.done = True

    def on_tick(self, clock: int, n_active: int, n_pending: int,
                free_pages: Optional[int], total_pages: Optional[int]
                ) -> None:
        self.occupancy.append({
            "clock": clock, "active": n_active, "pending": n_pending,
            "free_pages": free_pages, "total_pages": total_pages,
        })

    # -- aggregation ------------------------------------------------------
    def summary(self) -> dict:
        """Aggregate to the ``BENCH_serving.json`` per-run block: p50/p99
        TTFT (chunks + warm wall-seconds), inter-token gaps, queue wait,
        SLO attainment, spill/resume totals, mean pool occupancy."""
        recs = list(self.records.values())
        ttft_c = [r.ttft_chunks for r in recs if r.ttft_chunks is not None]
        ttft_s = [r.ttft_seconds for r in recs if r.ttft_seconds is not None]
        waits = [r.queue_wait_chunks for r in recs
                 if r.queue_wait_chunks is not None]
        gaps = [g for r in recs for g in r.itl_gaps_chunks]
        slo = [r.slo_ok for r in recs if r.slo_ok is not None]
        ttft_slo = [r.ttft_ok for r in recs if r.ttft_ok is not None]
        occ = [o for o in self.occupancy if o["total_pages"]]
        return {
            "sessions": len(recs),
            "finished": sum(r.done for r in recs),
            "tokens_out": sum(r.tokens_out for r in recs),
            "ttft_chunks": {"p50": percentile(ttft_c, 50),
                            "p99": percentile(ttft_c, 99)},
            "ttft_seconds_warm": {"p50": percentile(ttft_s, 50),
                                  "p99": percentile(ttft_s, 99),
                                  "n": len(ttft_s)},
            "ttft_compile_excluded": sum(r.ttft_compiled for r in recs),
            "itl_chunks": {"p50": percentile(gaps, 50),
                           "p99": percentile(gaps, 99)},
            "queue_wait_chunks": {"p50": percentile(waits, 50),
                                  "p99": percentile(waits, 99)},
            "slo": {
                "sessions_with_slo": len(slo),
                "attainment": (sum(slo) / len(slo)) if slo else None,
                "ttft_attainment": (sum(ttft_slo) / len(ttft_slo))
                if ttft_slo else None,
            },
            "spills": sum(r.spills for r in recs),
            "resumes": sum(r.resumes for r in recs),
            "spec_decode": self._spec_summary(recs),
            "pool_occupancy_mean": (
                sum(1.0 - o["free_pages"] / o["total_pages"] for o in occ)
                / len(occ)) if occ else None,
        }

    @staticmethod
    def _spec_summary(recs) -> Optional[dict]:
        """Speculative-decoding block: None when nothing speculated."""
        spec = [r for r in recs if r.spec_rounds]
        if not spec:
            return None
        rates = [r.acceptance_rate for r in spec
                 if r.acceptance_rate is not None]
        drafted = sum(r.spec_drafted for r in spec)
        accepted = sum(r.spec_accepted for r in spec)
        rounds = sum(r.spec_rounds for r in spec)
        return {
            "sessions": len(spec),
            "rounds": rounds,
            "drafted": drafted,
            "accepted": accepted,
            "acceptance_rate": (accepted / drafted) if drafted else None,
            "acceptance_rate_p50": percentile(rates, 50),
            "tokens_per_round": (
                (accepted + rounds) / rounds) if rounds else None,
        }

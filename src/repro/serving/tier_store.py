"""Host-side content-addressed tier store: the memory hierarchy below HBM.

The serving stack keeps every *active* session's cache state in the
scheduler's fixed-shape device ``DecodeState``.  This module is where
everything else lives: a capacity-bounded, LRU-evictable, host-RAM store
of content-addressed blobs, with an optional mmap'd disk directory as
the tier below that.  One mechanism serves three kinds of state:

* **Session snapshots** — a spilled/preempted session's entire slot
  state (``DecodeState.snapshot_slot``: bookkeeping rows + kv in the
  PHYSICAL representation, so int8 snapshots stay compressed on host
  and paged snapshots hold only the slot's live pages).  Keyed by a
  digest of the session content (prompt + extras + generated ids) and
  PINNED while the session is spilled: a pinned entry may demote to the
  disk tier but is never dropped — the session must be restorable.
* **Retired prefix pages** — refcount-0 prefix-sharing pages retire
  INTO the store under the same page-aligned rolling-hash chunk keys
  the resident prefix map uses, so a later admission with the same
  prompt prefix re-adopts their content (one page upload) instead of
  re-forwarding it: residency in the memory hierarchy, not refcount,
  decides reuse.
* **Admission snapshots** — for families whose post-admission slot
  state is a pure function of the prompt ids (the tconst/tlin resync
  rebuilds ctx/hist KV from raw tokens — ``tconst.admission_digest``),
  the cold admission's slot snapshot (+ prefill logits) is stored by
  prompt digest, turning re-admission of a known prompt into an O(1)
  restore with zero forward compute.

Capacity is enforced over the RAM tier in bytes; eviction is LRU.  With
``spill_dir`` set, evicted entries DEMOTE to ``spill_dir/<key-hex>/``
(one ``.npy`` per array, loaded back with ``np.load(mmap_mode="r")`` so
promotion reads lazily through the page cache) instead of being
dropped; a ``get`` that misses RAM promotes from disk.  The disk index
is rebuilt on construction, so a spill directory outlives the process.
Without a disk tier, unpinned entries are dropped at eviction (their
loss costs recompute, never correctness) and pinned entries are kept
even over capacity (documented: pins are a correctness obligation).
"""
from __future__ import annotations

import collections
import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, Iterable, Optional, Tuple

import numpy as np

_BK = "bk."          # flattened-snapshot prefixes (field names never
_KV = "kv."          # contain "." — kv/bookkeeping names are identifiers)
_META_FILE = "meta.json"


@dataclasses.dataclass
class Blob:
    """One store entry: named host arrays + a small JSON-able meta dict."""

    arrays: Dict[str, np.ndarray]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.arrays.values()))


def flatten_slot_snapshot(snap: Dict[str, Dict[str, np.ndarray]],
                          meta: Dict[str, Any]) -> Blob:
    """Flatten a host ``DecodeState.snapshot_slot`` result (the
    ``{"bookkeeping": ..., "kv": ...}`` two-dict form) into one Blob."""
    arrays: Dict[str, np.ndarray] = {}
    for name, v in snap["bookkeeping"].items():
        arrays[_BK + name] = np.asarray(v)
    for name, v in snap["kv"].items():
        arrays[_KV + name] = np.asarray(v)
    return Blob(arrays, dict(meta))


def unflatten_slot_snapshot(blob: Blob) -> Tuple[Dict[str, np.ndarray],
                                                 Dict[str, np.ndarray],
                                                 Dict[str, Any]]:
    """Inverse of :func:`flatten_slot_snapshot`:
    ``(bookkeeping_rows, kv_rows, meta)``.  Extra arrays without a
    partition prefix (e.g. an admission blob's ``logits``) are left out
    — read them from ``blob.arrays`` directly."""
    bk: Dict[str, np.ndarray] = {}
    kv: Dict[str, np.ndarray] = {}
    for name, v in blob.arrays.items():
        if name.startswith(_BK):
            bk[name[len(_BK):]] = v
        elif name.startswith(_KV):
            kv[name[len(_KV):]] = v
    return bk, kv, dict(blob.meta)


class TierStore:
    """Content-addressed LRU blob store: bounded host RAM over an
    optional mmap'd disk directory (see module docstring)."""

    def __init__(self, capacity_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0 (or None for "
                             "an unbounded RAM tier)")
        self.capacity_bytes = capacity_bytes
        self.spill_dir = Path(spill_dir) if spill_dir is not None else None
        self._ram: "collections.OrderedDict[bytes, Blob]" = \
            collections.OrderedDict()
        self._ram_bytes = 0
        self._pins: Dict[bytes, int] = {}
        self._disk: Dict[bytes, int] = {}        # key -> stored nbytes
        self.stats = {"puts": 0, "hits": 0, "misses": 0, "evictions": 0,
                      "demotions": 0, "promotions": 0}
        if self.spill_dir is not None:
            self.spill_dir.mkdir(parents=True, exist_ok=True)
            for d in self.spill_dir.iterdir():   # a spill dir is durable:
                meta_p = d / _META_FILE          # re-index existing entries
                if d.is_dir() and meta_p.exists():
                    with open(meta_p) as f:
                        meta = json.load(f)
                    self._disk[bytes.fromhex(d.name)] = int(
                        meta.get("__nbytes", 0))

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        return len(self._ram) + sum(1 for k in self._disk
                                    if k not in self._ram)

    def __contains__(self, key: bytes) -> bool:
        """Residency test (RAM or disk) WITHOUT touching LRU order —
        admission planning probes many keys it may not fetch."""
        return key in self._ram or key in self._disk

    @property
    def occupancy_bytes(self) -> int:
        return self._ram_bytes

    @property
    def disk_bytes(self) -> int:
        return int(sum(self._disk.values()))

    def pinned_keys(self) -> Iterable[bytes]:
        return tuple(self._pins)

    # -- pinning ------------------------------------------------------------
    def pin(self, key: bytes) -> None:
        """A pinned entry may demote to disk but is NEVER dropped (kept
        over capacity if there is no disk tier) — the contract that
        makes spilled sessions restorable.  Counted: pin/unpin nest."""
        assert key in self, "cannot pin a key the store does not hold"
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: bytes) -> None:
        n = self._pins.get(key, 0) - 1
        if n <= 0:
            self._pins.pop(key, None)
            # an entry kept over capacity ONLY by its pin loses that
            # excuse now — evict eagerly instead of letting it squat in
            # RAM until the next unrelated put
            self._evict_to_capacity()
        else:
            self._pins[key] = n

    # -- core ---------------------------------------------------------------
    def put(self, key: bytes, blob: Blob, pin: bool = False) -> None:
        """Insert/refresh ``key``.  Content-addressed: a re-put of a
        resident key carries identical content, so any disk copy stays
        valid (demotion skips the rewrite).  ``pin=True`` registers the
        pin BEFORE capacity enforcement — a put-then-pin pair could
        otherwise lose the entry to its own eviction pass when the blob
        alone exceeds capacity and there is no disk tier."""
        old = self._ram.pop(key, None)
        if old is not None:
            self._ram_bytes -= old.nbytes
        self._ram[key] = blob
        self._ram_bytes += blob.nbytes
        self.stats["puts"] += 1
        if pin:
            self._pins[key] = self._pins.get(key, 0) + 1
        self._evict_to_capacity()

    def get(self, key: bytes) -> Optional[Blob]:
        """Fetch (and LRU-touch) ``key``; a RAM miss promotes from the
        disk tier.  None when the content is in neither tier."""
        blob = self._ram.get(key)
        if blob is not None:
            self._ram.move_to_end(key)
            self.stats["hits"] += 1
            return blob
        if key in self._disk:
            blob = self._disk_read(key)
            self._ram[key] = blob
            self._ram_bytes += blob.nbytes
            self.stats["promotions"] += 1
            self.stats["hits"] += 1
            self._evict_to_capacity(keep=key)
            return blob
        self.stats["misses"] += 1
        return None

    def pop(self, key: bytes) -> Optional[Blob]:
        """Remove ``key`` from every tier (pins are cleared too)."""
        self._pins.pop(key, None)
        blob = self._ram.pop(key, None)
        if blob is not None:
            self._ram_bytes -= blob.nbytes
        if key in self._disk:
            disk_blob = self._disk_read(key) if blob is None else None
            self._disk_remove(key)
            blob = blob if blob is not None else disk_blob
        return blob

    def _evict_to_capacity(self, keep: Optional[bytes] = None) -> None:
        if self.capacity_bytes is None:
            return
        # LRU walk; an entry survives in RAM only if it is pinned AND
        # there is no disk tier to demote it to (or it is `keep`, the
        # entry a promotion is currently returning a reference to)
        skipped = []
        while self._ram_bytes > self.capacity_bytes and self._ram:
            key, blob = next(iter(self._ram.items()))
            if key == keep or (key in self._pins and
                               self.spill_dir is None):
                self._ram.move_to_end(key)
                skipped.append(key)
                if len(skipped) >= len(self._ram):
                    break                    # everything left must stay
                continue
            del self._ram[key]
            self._ram_bytes -= blob.nbytes
            if self.spill_dir is not None:
                self._disk_write(key, blob)
                self.stats["demotions"] += 1
            else:
                self.stats["evictions"] += 1

    # -- disk tier ----------------------------------------------------------
    def _entry_dir(self, key: bytes) -> Path:
        return self.spill_dir / key.hex()

    def _disk_write(self, key: bytes, blob: Blob) -> None:
        if key in self._disk:
            return                  # content-addressed: copy already valid
        d = self._entry_dir(key)
        d.mkdir(parents=True, exist_ok=True)
        for name, arr in blob.arrays.items():
            np.save(d / f"{name}.npy", np.ascontiguousarray(arr))
        meta = dict(blob.meta)
        meta["__nbytes"] = blob.nbytes
        meta["__arrays"] = sorted(blob.arrays)
        with open(d / _META_FILE, "w") as f:
            json.dump(meta, f)
        self._disk[key] = blob.nbytes

    def _disk_read(self, key: bytes) -> Blob:
        d = self._entry_dir(key)
        with open(d / _META_FILE) as f:
            meta = json.load(f)
        names = meta.pop("__arrays")
        meta.pop("__nbytes", None)
        arrays = {name: np.load(d / f"{name}.npy", mmap_mode="r")
                  for name in names}
        return Blob(arrays, meta)

    def _disk_remove(self, key: bytes) -> None:
        self._disk.pop(key, None)
        d = self._entry_dir(key)
        if d.exists():
            for p in d.iterdir():
                p.unlink()
            d.rmdir()

"""Speculative decoding: the Drafter seam.

The paper's amortized-O(1) cache-hit step is memory-bound — each token
pays a full weight/KV read for one token of arithmetic — so the next
raw-speed multiplier is to propose k tokens cheaply and VERIFY them in
one fixed-shape dispatch (``DecodeAPI.verify_chunk``).  The contract is
verify-exactness: a draft token is accepted iff it equals the token the
sequential decode would have sampled there (``spec_chunk`` replays the
slot's key chain against the verify logits), so speculation can change
wall-clock only — never a stream.  Draft QUALITY therefore only moves
the acceptance rate; a garbage drafter still makes one token of
progress per round (the bonus token IS the sequential sample).

Two drafters ship:

* :class:`NGramDrafter` — self-drafting from the session's own resident
  token window: the continuation after the last previous occurrence of
  the trailing n-gram.  Zero model cost, surprisingly strong on
  repeat-heavy text (code, transcripts, structured output).
* :class:`TConstModelDrafter` — a reduced small-W tconst model
  (Katharopoulos-style small-state recurrence is the motivation: the
  drafter's O(1) cache makes its k steps cheap) with its OWN
  ``DecodeState``, caught up on accepted tokens by forced decode steps
  (bucketed fixed shapes) and rolled forward k greedy steps to propose.
  Exactness never depends on its weights — they may be random.

The scheduler drives the per-slot protocol: ``admit`` (prompt at
admission/resume), ``observe`` (accepted tokens after each verify
round), ``release`` (slot freed / spilled), ``propose_batch`` (one
(slots, k) proposal per round).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = ["Drafter", "NGramDrafter", "TConstModelDrafter", "get_drafter"]


class Drafter:
    """Per-slot draft proposer (host-side protocol object).

    Implementations keep whatever per-slot state they need, keyed by
    slot index; the scheduler guarantees ``admit``/``release`` bracket a
    slot's residency and ``observe`` carries exactly the accepted
    (delivered) tokens in stream order — so a drafter's view of slot s
    is always a prefix-faithful copy of the session's token history.
    """

    name = "base"

    def admit(self, slot: int, tokens: Sequence[int]) -> None:
        """Slot ``slot`` begins a residency with token history
        ``tokens`` (prompt + any tokens generated before a spill)."""
        raise NotImplementedError

    def observe(self, slot: int, tokens: Sequence[int]) -> None:
        """Accepted tokens appended to slot ``slot``'s stream."""
        raise NotImplementedError

    def release(self, slot: int) -> None:
        """Slot freed (retire or spill): drop its state."""
        raise NotImplementedError

    def propose_batch(self, k: int) -> np.ndarray:
        """(slots, k) int32 proposals — every slot, every round (empty
        slots propose garbage; the scheduler masks them out)."""
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Self-drafting from the resident window: propose the continuation
    that followed the LAST previous occurrence of the trailing n-gram
    (orders ``3, 2, 1``), falling back to repeating the final token.
    The search window is bounded (``window`` trailing tokens) so a
    round's host cost is O(slots * window)."""

    name = "ngram"

    def __init__(self, slots: int, window: int = 512,
                 orders: Sequence[int] = (3, 2, 1)):
        self.slots = slots
        self.window = window
        self.orders = tuple(orders)
        self._hist: List[Optional[List[int]]] = [None] * slots

    def admit(self, slot: int, tokens: Sequence[int]) -> None:
        self._hist[slot] = [int(t) for t in tokens][-self.window:]

    def observe(self, slot: int, tokens: Sequence[int]) -> None:
        h = self._hist[slot]
        if h is None:
            return
        h.extend(int(t) for t in tokens)
        if len(h) > self.window:
            del h[:len(h) - self.window]

    def release(self, slot: int) -> None:
        self._hist[slot] = None

    def _propose_one(self, h: List[int], k: int) -> List[int]:
        if not h:
            return [0] * k
        for n in self.orders:
            if len(h) <= n:
                continue
            suffix = h[-n:]
            # last previous occurrence of the trailing n-gram (ending
            # strictly before the end, so it has a continuation)
            for i in range(len(h) - n - 1, -1, -1):
                if h[i:i + n] == suffix:
                    cont = h[i + n:i + n + k]
                    if cont:
                        return (cont + [cont[-1]] * k)[:k]
                    break
        return [h[-1]] * k

    def propose_batch(self, k: int) -> np.ndarray:
        out = np.zeros((self.slots, k), np.int32)
        for s, h in enumerate(self._hist):
            if h is not None:
                out[s] = self._propose_one(h, k)
        return out


class TConstModelDrafter(Drafter):
    """Model drafter: a reduced small-W tconst config with its own O(1)
    decode state, one slot per scheduler slot.  Catch-up feeds pending
    tokens (prompt at admit, accepted tokens after each round) through
    FORCED decode steps — bucketed to power-of-two lengths so the
    compile count stays logarithmic — then ``propose_batch`` snapshots
    the state and rolls k greedy steps forward (the snapshot is simply
    not kept, so mispredicted draft steps never corrupt catch-up
    state).  Weights are randomly initialised by default: verify-
    exactness makes draft quality a THROUGHPUT knob, not a correctness
    one, and the harness exploits that to test the machinery without a
    trained checkpoint."""

    name = "tconst"

    def __init__(self, slots: int, vocab: int, max_len: int,
                 seed: int = 0, params: Any = None,
                 cfg: Any = None):
        import jax
        import jax.numpy as jnp
        from repro.config import get_config, reduced
        from repro.models.api import build_decode, build_model
        self.slots = slots
        self.max_len = max_len
        if cfg is None:
            cfg = reduced(get_config("tconst_41m"), dtype="float32",
                          vocab_size=vocab)
        self.cfg = cfg
        self.decode = build_decode(cfg)
        if params is None:
            params = build_model(cfg).init(jax.random.PRNGKey(seed))
        self.params = params
        self.state = self.decode.init_state(slots, max_len)
        self._fresh = self.state
        self._clear_jit = jax.jit(
            lambda st, keep: st.where_rows(keep, self._fresh))
        # host-side pending (not-yet-fed) tokens + fed counts per slot
        self._pending: List[List[int]] = [[] for _ in range(slots)]
        self._fed = np.zeros((slots,), np.int64)
        self._active = np.zeros((slots,), bool)
        self._last = np.zeros((slots,), np.int32)
        self._jits: Dict[int, Any] = {}
        self._draft_jit = jax.jit(self._draft, static_argnames=("k",))
        self._jnp = jnp

    # -- jitted bodies ---------------------------------------------------
    def _catchup(self, params, state, toks, n_valid, active):
        """Force-feed ``toks`` (B, T): step c feeds toks[:, c] for rows
        with c < n_valid; other rows freeze bit-identically."""
        import jax
        jnp = self._jnp

        def body(c, state):
            live = jnp.logical_and(active, c < n_valid)
            _, new_state = self.decode.step(params, state, toks[:, c])
            return new_state.where_rows(live, state)

        return jax.lax.fori_loop(0, toks.shape[1], body, state)

    def _draft(self, params, state, last, k: int):
        """k greedy steps from ``state`` (state is discarded by the
        caller — the snapshot semantics)."""
        import jax
        jnp = self._jnp

        def body(carry, _):
            state, tok = carry
            logits, state = self.decode.step(params, state, tok)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (state, nxt), nxt

        (_, _), toks = jax.lax.scan(body, (state, last), None, length=k)
        return jnp.moveaxis(toks, 0, 1)

    # -- protocol --------------------------------------------------------
    def admit(self, slot: int, tokens: Sequence[int]) -> None:
        import jax.numpy as jnp
        keep = np.ones((self.slots,), bool)
        keep[slot] = False
        self.state = self._clear_jit(self.state, jnp.asarray(keep))
        self._pending[slot] = [int(t) for t in tokens]
        self._fed[slot] = 0
        self._active[slot] = True

    def observe(self, slot: int, tokens: Sequence[int]) -> None:
        if self._active[slot]:
            self._pending[slot].extend(int(t) for t in tokens)

    def release(self, slot: int) -> None:
        self._pending[slot] = []
        self._fed[slot] = 0
        self._active[slot] = False

    def _flush(self) -> None:
        """Catch every active slot up on its pending tokens, bucketed."""
        import jax.numpy as jnp
        # overflow guard: a slot whose history outgrows the drafter's
        # buffers stops being modelled (repeat-last fallback) — the
        # served model's exactness is unaffected
        for s in range(self.slots):
            if self._active[s] and \
                    self._fed[s] + len(self._pending[s]) > self.max_len - 1:
                self._active[s] = False
                self._pending[s] = []
        longest = max((len(p) for s, p in enumerate(self._pending)
                       if self._active[s]), default=0)
        if not longest:
            return
        T = 1
        while T < longest:
            T *= 2
        toks = np.zeros((self.slots, T), np.int32)
        n_valid = np.zeros((self.slots,), np.int32)
        run = np.zeros((self.slots,), bool)
        for s in range(self.slots):
            if self._active[s] and self._pending[s]:
                p = self._pending[s]
                toks[s, :len(p)] = p
                n_valid[s] = len(p)
                run[s] = True
                self._last[s] = p[-1]
                self._fed[s] += len(p)
                self._pending[s] = []
        import jax
        fn = self._jits.get(T)
        if fn is None:
            fn = jax.jit(self._catchup)
            self._jits[T] = fn
        self.state = fn(self.params, self.state, jnp.asarray(toks),
                        jnp.asarray(n_valid), jnp.asarray(run))

    def propose_batch(self, k: int) -> np.ndarray:
        import jax.numpy as jnp
        self._flush()
        if not self._active.any():
            return np.zeros((self.slots, k), np.int32)
        draft = self._draft_jit(self.params, self.state,
                                jnp.asarray(self._last), k=k)
        out = np.array(draft, np.int32)          # copy: jax arrays are read-only
        out[~self._active] = 0
        return out


def get_drafter(name: str, *, slots: int, vocab: int, max_len: int,
                seed: int = 0) -> Drafter:
    """Factory behind ``serve.py --drafter``."""
    if name == "ngram":
        return NGramDrafter(slots)
    if name == "tconst":
        return TConstModelDrafter(slots, vocab=vocab, max_len=max_len,
                                  seed=seed)
    raise ValueError(f"unknown drafter {name!r} (expected ngram|tconst)")

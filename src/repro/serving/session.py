"""Per-request inference sessions for the streaming serving path.

A :class:`Session` is the unit the scheduler admits into a decode slot:
it owns its prompt (any length), sampling parameters, token budget and
an optional streaming callback fired once per generated token.  Sessions
are plain host-side objects — all device state lives in the scheduler's
fixed-shape :class:`repro.models.api.DecodeState`.

Typical use (see ``repro.launch.serve --sessions`` for a runnable demo)::

    sched = SlotScheduler(build_model(cfg).decode, params,
                          slots=4, max_len=512)
    s = sched.submit(Session(prompt, max_new_tokens=32,
                             on_token=lambda sess, t: print(t)))
    sched.run()            # continuous batching; tokens stream via callback
    print(s.tokens)
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional

import numpy as np

_IDS = itertools.count()


@dataclasses.dataclass
class Session:
    """One generation request.

    prompt: 1-D int32 token ids (any length — slots in the same batch may
    have different prompt lengths and resync phases).
    max_new_tokens: total tokens to generate, INCLUDING the first token
    sampled from the prefill logits.
    temperature: sampling temperature (<= 0 means greedy).
    eos_id: optional end-of-sequence token id — generating it finishes
    the session early (the EOS itself is delivered).  On device, the
    slot's ``done`` flag freezes it for the rest of the decode chunk;
    the scheduler evicts it at the chunk boundary.
    on_token: optional ``f(session, token)`` streaming callback.
    extras: per-request model inputs beyond tokens (e.g. ``audio_feats``
    for the encoder-decoder, ``vision_embeds``/``vision_mask`` for VLMs).
    seed: optional per-session sampling seed.  When set, the session's
    PRNG key chain is ``PRNGKey(seed)`` advanced once per generated
    token — a pure function of this session's own progress, so replaying
    the same session (any slot, any policy, after any number of
    spill/resume cycles) yields the identical token stream.  When None,
    the chain derives from the scheduler seed and ``sid``.
    priority: scheduling weight (higher = more urgent); only consulted
    by priority-aware policies, never by the FIFO baseline.
    slo_ttft_chunks / slo_itl_chunks: optional SLO targets in scheduler
    chunk units — deadline for the first token after submission, and the
    max tolerated inter-token gap.  Pure metadata: policies may order
    work by them and telemetry scores attainment, but the scheduler
    mechanism never inspects them.
    """

    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    on_token: Optional[Callable[["Session", int], None]] = None
    extras: Optional[Dict[str, Any]] = None
    seed: Optional[int] = None
    priority: int = 0
    slo_ttft_chunks: Optional[int] = None
    slo_itl_chunks: Optional[int] = None

    # filled by the scheduler -----------------------------------------------
    sid: int = dataclasses.field(default_factory=lambda: next(_IDS))
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    # session tiering (scheduler-managed): while spilled, ``snap_key`` is
    # the tier-store key of the session's pinned slot snapshot and
    # ``slot`` is None; a later admission restores it into ANY free slot
    # and clears the key.  ``spills``/``resumes`` count the completed
    # HBM -> host -> HBM cycles (the serve demo's per-session report).
    snap_key: Optional[bytes] = None
    spills: int = 0
    resumes: int = 0
    # submit-time scheduler clock (chunk units) — set by ``submit``; the
    # anchor for TTFT/queue-wait accounting and deadline slack.
    submit_clock: Optional[int] = None
    # saved per-slot PRNG key across a spill (the chain position is
    # ``len(tokens)``, so restoring this key resumes the exact stream).
    sample_chain: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.max_new_tokens >= 1, "need at least the prefill token"

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.tokens)

    def deliver(self, tokens) -> None:
        """Append generated tokens (clipped to the budget, truncated at
        ``eos_id``) and stream them through the callback; marks the
        session done at budget or EOS."""
        for t in list(tokens)[: self.remaining]:
            self.tokens.append(int(t))
            if self.on_token is not None:
                self.on_token(self, int(t))
            if self.eos_id is not None and int(t) == self.eos_id:
                self.done = True
                return
        if self.remaining == 0:
            self.done = True

"""Batched generation engine on top of the session/scheduler serving API.

The serving stack has three layers:

* ``repro.models.api.DecodeAPI`` — the per-model decode protocol.  Its
  ``step`` fuses the TConst W_og-boundary resync ON DEVICE through the
  batched compacted ``sync_rows`` (ALL boundary rows' bookkeeping is
  gathered in one dispatch, resynced at the bucketed pending count, and
  the fresh KV written back through the layout — non-boundary rows are
  never computed), and ``decode_chunk`` scans it so a chunk of k tokens
  is ONE dispatch with zero per-token host round-trips.  The physical
  cache representation is a pluggable ``repro.models.layouts`` backend
  (dense / paged / int8 / paged_int8) that the decode kernels consume
  LAYOUT-NATIVELY via per-field KVViews: paged pools are walked through
  the page table in-kernel, int8 dequant rides the QK/AV loops, and no
  step materialises the dense ``slots x max_len`` logical cache.
* ``repro.serving.scheduler.SlotScheduler`` + ``repro.serving.session``
  — continuous batching: per-request sessions with their own prompt
  lengths / sampling params / EOS ids / streaming callbacks, admitted
  and evicted mid-flight into a fixed-shape slotted batch (paged
  layout: admission/eviction is page-map surgery).
* :class:`Engine` (this module) — the thin uniform-batch wrapper kept
  for benchmarks and examples: same-length prompts in, ``(B, n)`` ids
  out.  ``generate(record_stats=False)`` uses the chunked zero-sync
  path; ``record_stats=True`` switches to the instrumented step-at-a-
  time reference path that times cache hits and misses separately —
  the amortized-O(1) schedule of §4 (``W_og - 1`` constant-time hits,
  then ONE linear-time miss) for the Fig 8 latency split.

Cache accounting (``cache_bytes``) reads the ``DecodeState`` kv /
bookkeeping partition in its PHYSICAL layout — paged pools and int8
scales report their true bytes, and the id buffer and counters are
excluded by construction, not by name-matching.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI, build_decode, decode_chunk, spec_chunk


@dataclasses.dataclass
class StepStats:
    kind: str      # "prefill" | "hit" | "miss" | "chunk" | "admit" | "spill"
    seconds: float
    tokens: int = 1        # tokens produced by this entry (chunks: many)
    # True when this entry's wall-clock includes the one-time jit compile
    # of its dispatch (first chunk of a shape, first prefill of a prompt
    # length, ...).  Throughput aggregation must exclude these entries
    # (or medianize) — BENCH_inference.json numbers do.
    compiled: bool = False
    # "admit" entries: prompt positions the admission actually FORWARDED
    # (chunked KV-conditioned prefill: the unshared tail padded to the
    # chunk grid; one-shot prefill: the whole prompt; tier-store restore:
    # ZERO — the whole point) — the tail-only compute accounting asserted
    # in tests/test_prefill_chunked.py and recorded under
    # "chunked_prefill" in BENCH_inference.json.
    forward_tokens: Optional[int] = None
    # "admit" entries: where the slot state came from — "cold" (prefill
    # forward), "resume" (a spilled session's pinned tier-store snapshot
    # restored into a free slot), or "store" (content-addressed admission
    # cache hit: a known prompt's post-prefill state restored, zero
    # forward compute).  None for non-admit kinds.
    source: Optional[str] = None


def tag_compiled(warm: set, kind: str, sig: Any = None) -> bool:
    """True exactly for the first dispatch of each (kind, signature) —
    the one whose wall-clock includes the jit compile.  One rule shared
    by the Engine and the SlotScheduler so the tagging cannot drift."""
    key = (kind, sig)
    fresh = key not in warm
    warm.add(key)
    return fresh


class Engine:
    def __init__(self, api: ModelAPI, params: Any, max_len: int,
                 sample_temperature: float = 0.0, seed: int = 0,
                 layout: Optional[Any] = None,
                 prefill_chunk: Optional[int] = None,
                 mesh: Optional[Any] = None):
        self.api = api
        # prefill_chunk rides on the decode protocol: the Engine's own
        # uniform-batch prefill is one fixed-shape dispatch already, but
        # a SlotScheduler built from this engine's decode inherits the
        # chunked-admission default.  mesh (a jax Mesh or MeshContext)
        # makes the SAME decode path run sharded — see docs/sharding.md.
        self.decode = build_decode(api.cfg, layout,
                                   prefill_chunk=prefill_chunk, mesh=mesh)
        self.params = params
        self.max_len = max_len
        self.temperature = sample_temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b: self.decode.prefill(p, b, max_len))
        self._step = jax.jit(self.decode.raw_step)     # hit (no sync check)
        self._mask = jax.jit(self.decode.sync_mask)
        self._sync = jax.jit(self.decode.maybe_sync)   # miss (compacted)
        self._chunk = jax.jit(
            functools.partial(decode_chunk, self.decode),
            static_argnames=("n_steps",))
        self.stats: List[StepStats] = []
        self._warm: set = set()    # (kind, shape-signature) seen -> compiled

    def _stat(self, kind: str, seconds: float, sig: Any = None,
              tokens: int = 1) -> None:
        """Record a StepStats entry, tagging the first dispatch of each
        (kind, signature) as ``compiled`` so aggregations can drop the
        one-time jit cost."""
        self.stats.append(StepStats(kind, seconds, tokens=tokens,
                                    compiled=tag_compiled(self._warm, kind,
                                                          sig)))

    def _select(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, Any], n_tokens: int,
                 record_stats: bool = False) -> np.ndarray:
        """batch: prompt inputs (same-length prompts).  Returns
        (B, n_tokens) generated ids."""
        t0 = time.perf_counter()
        logits, state = jax.block_until_ready(
            self._prefill(self.params, batch))
        if record_stats:
            self._stat("prefill", time.perf_counter() - t0,
                       sig=batch["tokens"].shape)
        token = self._select(logits)
        if record_stats:
            return self._generate_instrumented(state, token, n_tokens)
        return self._generate_chunked(state, token, n_tokens)

    def _generate_chunked(self, state, token, n_tokens: int) -> np.ndarray:
        """Fast path: the remaining n_tokens - 1 steps run as ONE jitted
        lax.scan — the compacted resync fires inside the scanned step, so
        there are zero per-token host syncs."""
        B = token.shape[0]
        temps = jnp.full((B,), self.temperature, jnp.float32)
        active = jnp.ones((B,), bool)
        self.key, sub = jax.random.split(self.key)
        toks, state, _ = self._chunk(self.params, state, token, sub, temps,
                                     active, n_steps=n_tokens - 1)
        return np.concatenate(
            [np.asarray(token)[:, None], np.asarray(toks)], axis=1)

    def _generate_instrumented(self, state, token, n_tokens: int
                               ) -> np.ndarray:
        """Reference path: one dispatch per token, resync decided on host,
        so each hit/miss is timed separately (paper Fig 8)."""
        out = [token]
        for _ in range(n_tokens - 1):
            if bool(np.asarray(self._mask(state)).any()):
                t0 = time.perf_counter()
                state = jax.block_until_ready(
                    self._sync(self.params, state))
                self._stat("miss", time.perf_counter() - t0)
            t0 = time.perf_counter()
            logits, state = jax.block_until_ready(
                self._step(self.params, state, token))
            self._stat("hit", time.perf_counter() - t0)
            token = self._select(logits)
            out.append(token)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------------------------
    def generate_speculative(self, batch: Dict[str, Any], n_tokens: int,
                             k: int = 4, drafter: Optional[Any] = None
                             ) -> np.ndarray:
        """Speculative generation, token-identical to :meth:`generate`:
        each round drafts k tokens per row (``drafter``; default: a
        fresh per-row :class:`~repro.serving.speculative.NGramDrafter`)
        and verifies them in ONE ``spec_chunk`` dispatch, committing the
        verify-exact accepted prefix + bonus token.

        GREEDY ONLY: the Engine samples with one SHARED batch key, and a
        shared-key categorical draw at verify position c depends on the
        whole batch's acceptance positions — it cannot be replayed
        exactly.  The scheduler path (per-slot key chains) is exact for
        any temperature; here a ``sample_temperature > 0`` raises.
        Rows that reach ``n_tokens`` freeze (bit-identically) while
        stragglers catch up.  Returns (B, n_tokens) ids; the round
        count of the last call lands in ``self.spec_rounds``."""
        if self.temperature > 0.0:
            raise ValueError(
                "speculative Engine generation is greedy-only: the "
                "shared batch sampling key cannot replay per-row "
                "accepted positions exactly — use the SlotScheduler "
                "(per-slot key chains) for sampled speculative decoding")
        if not self.decode.supports_speculative():
            raise ValueError(
                "this model family cannot decode speculatively "
                "(recurrent state cannot roll back)")
        prompt = np.asarray(batch["tokens"])
        need = prompt.shape[1] + n_tokens + 2 * k + 1
        if need > self.max_len:
            raise ValueError(
                f"prompt {prompt.shape[1]} + n_tokens {n_tokens} + "
                f"speculative headroom {2 * k + 1} exceeds max_len "
                f"{self.max_len}")
        from repro.serving.speculative import NGramDrafter
        logits, state = jax.block_until_ready(
            self._prefill(self.params, batch))
        token = self._select(logits)
        B = token.shape[0]
        if drafter is None:
            drafter = NGramDrafter(B)
        spec = getattr(self, "_spec", None)
        if spec is None:
            spec = jax.jit(functools.partial(spec_chunk, self.decode))
            self._spec = spec
        t_host = np.asarray(token)
        out: List[List[int]] = [[int(t_host[b])] for b in range(B)]
        for b in range(B):
            drafter.admit(b, prompt[b].tolist() + [int(t_host[b])])
        temps = jnp.full((B,), self.temperature, jnp.float32)
        rounds = 0
        while min(len(o) for o in out) < n_tokens:
            active = jnp.asarray(
                np.array([len(o) < n_tokens for o in out]))
            draft = drafter.propose_batch(k)
            t0 = time.perf_counter()
            toks, m, token, state, self.key = spec(
                self.params, state, token, jnp.asarray(draft), self.key,
                temps, active)
            hm = np.asarray(m)
            ht = np.asarray(toks)
            self._stat("spec_chunk", time.perf_counter() - t0,
                       tokens=int(hm.sum()))
            for b in range(B):
                if hm[b]:
                    acc = ht[b, :hm[b]].tolist()
                    out[b].extend(acc)
                    drafter.observe(b, acc)
            rounds += 1
        self.spec_rounds = rounds
        return np.asarray([o[:n_tokens] for o in out], np.int32)

    # ------------------------------------------------------------------
    def time_chunked_decode(self, batch: Dict[str, Any], n_tokens: int
                            ) -> float:
        """Wall-clock seconds of the (n_tokens - 1)-token decode chunk
        alone — ONE dispatch, prefill and compile excluded.  This is the
        per-token quantity that is O(1) in context length for tconst."""
        logits, state = jax.block_until_ready(
            self._prefill(self.params, batch))
        token = self._select(logits)
        B = token.shape[0]
        temps = jnp.full((B,), self.temperature, jnp.float32)
        active = jnp.ones((B,), bool)
        self.key, sub = jax.random.split(self.key)
        args = (self.params, state, token, sub, temps, active)
        jax.block_until_ready(
            self._chunk(*args, n_steps=n_tokens - 1))    # warm-up/compile
        t0 = time.perf_counter()
        jax.block_until_ready(self._chunk(*args, n_steps=n_tokens - 1))
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def cache_bytes(self, batch_size: int) -> int:
        """KV-cache footprint at max_len (paper Fig 8g) in the engine's
        physical layout, from the DecodeState kv/bookkeeping partition
        (no allocation)."""
        state = jax.eval_shape(
            lambda: self.decode.init_state(batch_size, self.max_len))
        return state.kv_bytes()

"""Batched autoregressive serving engine.

Drives prefill -> decode steps for any ModelAPI; for TConst-mode models it
interposes the paper's periodic global synchronisation (`resync`) every
``W_og`` generated tokens — the amortized-O(1) schedule of §4:
``W_og - 1`` constant-time cache-hit steps, then ONE linear-time cache
miss.  The engine jit-compiles the three stages separately so the
benchmark harness can time hits and misses independently (paper Fig 8).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import ModelAPI


@dataclasses.dataclass
class StepStats:
    kind: str              # "prefill" | "hit" | "miss"
    seconds: float


class Engine:
    def __init__(self, api: ModelAPI, params: Any, max_len: int,
                 sample_temperature: float = 0.0, seed: int = 0):
        self.api = api
        self.params = params
        self.max_len = max_len
        self.temperature = sample_temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, b, max_len))
        self._decode = jax.jit(api.decode_step)
        self._resync = jax.jit(api.resync)
        self.stats: List[StepStats] = []

    def _select(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict[str, Any], n_tokens: int,
                 record_stats: bool = False) -> np.ndarray:
        """batch: prompt inputs (same-length prompts).  Returns
        (B, n_tokens) generated ids."""
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(
            self._prefill(self.params, batch))
        if record_stats:
            self.stats.append(StepStats("prefill", time.perf_counter() - t0))
        out = []
        token = self._select(logits)
        out.append(token)
        for _ in range(n_tokens - 1):
            kind = "hit"
            if bool(np.asarray(self.api.needs_resync(cache)).all()):
                t0 = time.perf_counter()
                cache = jax.block_until_ready(
                    self._resync(self.params, cache))
                if record_stats:
                    self.stats.append(
                        StepStats("miss", time.perf_counter() - t0))
            t0 = time.perf_counter()
            logits, cache = jax.block_until_ready(
                self._decode(self.params, cache, token))
            if record_stats:
                self.stats.append(StepStats(kind, time.perf_counter() - t0))
            token = self._select(logits)
            out.append(token)
        return np.stack([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------------------------
    def cache_bytes(self, batch_size: int) -> int:
        """KV-cache footprint of this model at max_len (paper Fig 8g)."""
        cache = jax.eval_shape(
            lambda: self.api.init_cache(batch_size, self.max_len))
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            name = str(path[-1])
            if "tokens" in name or "len" in name or "valid" in name:
                continue   # id buffer / bookkeeping, not KV cache
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        return total

"""deepseek-moe-16b [arXiv:2401.06066] — 2 shared + 64 routed top-6,
fine-grained experts, first layer dense."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("deepseek_moe_16b")
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        arch_type="moe",
        source="[arXiv:2401.06066]",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,             # dense-layer FFN width
        moe_d_ff=1408,          # fine-grained expert width
        vocab_size=102400,
        n_experts=64,
        n_experts_per_tok=6,
        n_shared_experts=2,
        first_dense_layers=1,
        attention_mode="full",
        rope_theta=10000.0,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 28 = 7 x 4
    )

"""qwen2-vl-2b [arXiv:2409.12191] — M-RoPE, dynamic resolution.  The
ViT vision encoder is a stub; input_specs supplies patch embeddings and a
placeholder mask (DESIGN.md carve-out)."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("qwen2_vl_2b")
def qwen2_vl_2b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        source="[arXiv:2409.12191]",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        frontend="vision_stub",
        frontend_tokens=256,     # patch embeddings per image
        frontend_dim=1280,       # ViT output width before the projector
        mrope=True,
        mrope_sections=(16, 24, 24),   # t/h/w bands; sum = head_dim//2
        attention_mode="full",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 28 = 7 x 4
    )

"""gemma3-4b [hf:google/gemma-3-1b-pt family] — 5 local : 1 global
attention pattern, 1024-token local window, 262k vocab."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("gemma3_4b")
def gemma3_4b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        arch_type="dense",
        source="[hf:google/gemma-3-1b-pt]",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        d_ff=10240,
        vocab_size=262144,
        head_dim=256,
        attention_mode="sliding",
        sliding_window=1024,
        local_global_ratio=5,    # 5 local then 1 global, repeating
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        tconst=TConstConfig(w_oh=256, w_og=256, h=0),
    )

"""smollm-360m [hf:HuggingFaceTB/SmolLM-135M family] — llama-arch small."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("smollm_360m")
def smollm_360m() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        arch_type="dense",
        source="[hf:HuggingFaceTB/SmolLM-135M]",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        attention_mode="full",
        rope_theta=10000.0,
        tie_embeddings=True,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 32 = 8 x 4
    )

"""minicpm-2b [arXiv:2404.06395] — llama-like; trained with the WSD
(warmup-stable-decay) schedule implemented in repro.training.schedules."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("minicpm_2b")
def minicpm_2b() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        arch_type="dense",
        source="[arXiv:2404.06395]",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        attention_mode="full",
        rope_theta=10000.0,
        tie_embeddings=True,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 40 = 10 x 4
    )

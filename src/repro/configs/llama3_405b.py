"""llama3-405b [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("llama3_405b")
def llama3_405b() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        arch_type="dense",
        source="[arXiv:2407.21783]",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        attention_mode="full",
        rope_theta=500_000.0,
        # TConst integration: 126 = 42 blocks x (h=1 + 2); pure full
        # attention otherwise, so long_500k REQUIRES tconst mode.
        tconst=TConstConfig(w_oh=256, w_og=256, h=1),
    )

"""mamba2-130m [arXiv:2405.21060] — attention-free SSD."""
from repro.config import ModelConfig, register_arch


@register_arch("mamba2_130m")
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        arch_type="ssm",
        source="[arXiv:2405.21060]",
        n_layers=24,
        d_model=768,
        n_heads=1,              # attention-free; unused
        n_kv_heads=1,
        d_ff=0,                 # pure mixer layers (no separate FFN)
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=64,
        attention_mode="full",  # ignored: attention-free (DESIGN.md §4)
    )

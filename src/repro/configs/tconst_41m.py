"""The paper's own ~41M-parameter configuration (paper §6.2.1): GPT-2
vocab, n_embd 432, 12 heads, equivalent depth 8 = 2 TConst blocks with
internal depth H=2, observation windows W_oh = W_og = 256 (the `512-0.5`
variant).  Tied embeddings give ~39.6M parameters."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("tconst_41m")
def tconst_41m() -> ModelConfig:
    return ModelConfig(
        name="tconst-41m",
        arch_type="dense",
        source="[this paper, §6.2.1]",
        n_layers=8,
        d_model=432,
        n_heads=12,
        n_kv_heads=12,
        d_ff=1728,
        vocab_size=50257,
        attention_mode="tconst",
        tie_embeddings=True,
        rope_theta=10000.0,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 8 = 2 x 4
    )

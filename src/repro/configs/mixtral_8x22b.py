"""mixtral-8x22b [arXiv:2401.04088] — 8 experts top-2, SWA."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("mixtral_8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        arch_type="moe",
        source="[arXiv:2401.04088]",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        moe_d_ff=16384,
        vocab_size=32768,
        n_experts=8,
        n_experts_per_tok=2,
        attention_mode="sliding",
        sliding_window=4096,
        rope_theta=1_000_000.0,
        # TConst integration: 56 = 14 blocks x (h=2 + 2)
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),
    )

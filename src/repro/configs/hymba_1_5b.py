"""hymba-1.5b [arXiv:2411.13676] — parallel attention + mamba heads per
layer (hybrid).  Simplifications noted in DESIGN.md: meta-tokens omitted;
all attention layers use SWA (the original keeps 3 global layers)."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("hymba_1_5b")
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        arch_type="hybrid",
        source="[arXiv:2411.13676]",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        hybrid_parallel=True,
        ssm_state=16,
        ssm_head_dim=64,
        ssm_expand=1,           # parallel heads: mamba path at 1x width
        ssm_conv=4,
        ssm_chunk=64,
        attention_mode="sliding",
        sliding_window=1024,
        rope_theta=10000.0,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),  # 32 = 8 x 4
    )

"""whisper-small [arXiv:2212.04356] — enc-dec; conv frontend is a stub
(input_specs supplies post-conv frame embeddings, DESIGN.md carve-out)."""
from repro.config import ModelConfig, TConstConfig, register_arch


@register_arch("whisper_small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        arch_type="audio",
        source="[arXiv:2212.04356]",
        n_layers=12,            # decoder layers
        encoder_layers=12,
        encoder_seq=1500,       # 30 s of audio after the conv frontend
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        frontend="audio_stub",
        frontend_tokens=1500,
        frontend_dim=768,
        attention_mode="full",
        sliding_window=4096,    # used by the long-decode sliding variant
        tie_embeddings=True,
        tconst=TConstConfig(w_oh=256, w_og=256, h=2),
    )

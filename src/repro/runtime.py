"""Global runtime flags (kernel routing, interpret mode).

Environment overrides (read once at import) let CI exercise the Pallas
kernels without code changes:

* ``REPRO_USE_PALLAS=1``       — route hot attention paths via Pallas even
  off-TPU (paired with interpret mode this is the ``pallas-interpret`` CI
  job that runs the kernel parity suites on every PR).
* ``REPRO_PALLAS_INTERPRET=0`` — force compiled Pallas (TPU only).
"""
from __future__ import annotations

import dataclasses
import os


def _env_bool(name: str, default: bool) -> bool:
    val = os.environ.get(name)
    if val is None:
        return default
    return val.strip().lower() not in ("0", "false", "no", "")


@dataclasses.dataclass
class Flags:
    use_pallas: bool = False          # route hot attention paths via Pallas
    pallas_interpret: bool = True     # CPU container: interpret=True


flags = Flags(
    use_pallas=_env_bool("REPRO_USE_PALLAS", False),
    pallas_interpret=_env_bool("REPRO_PALLAS_INTERPRET", True),
)

"""Global runtime flags (kernel routing, interpret mode)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Flags:
    use_pallas: bool = False          # route hot attention paths via Pallas
    pallas_interpret: bool = True     # CPU container: interpret=True


flags = Flags()

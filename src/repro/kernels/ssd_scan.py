"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

The SSD decomposition [arXiv:2405.21060] splits the selective-scan into
(i) intra-chunk dense work — decay-masked (C B^T) score matmuls, ideal for
the MXU — and (ii) a cheap inter-chunk recurrence over per-chunk states.
This kernel computes (i): for one (batch, head, chunk) it fuses the
cumulative log-decay, the masked score matrix, the intra-chunk output and
the chunk-final state, entirely in VMEM (Q x max(P, N) working set).

The inter-chunk recurrence (a length-``nc`` ``jax.lax.scan`` over
(H, P, N) states) and the carried-state correction stay in XLA — they are
O(L/Q) and bandwidth-trivial.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _ssd_chunk_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, st_ref):
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    da = da_ref[0, 0, 0].astype(jnp.float32)          # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)               # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)               # (Q, N)
    Q = xdt.shape[0]

    cs = jnp.cumsum(da)                               # (Q,)
    diff = cs[:, None] - cs[None, :]                  # (Q, Q)
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = col <= row
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * decay, xdt,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    decay_end = jnp.exp(cs[-1] - cs)                  # (Q,)
    state = jax.lax.dot_general(xdt, b * decay_end[:, None],
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = state.astype(st_ref.dtype)


def ssd_intra_chunk_pallas(xdt: jax.Array, da: jax.Array, b: jax.Array,
                           c: jax.Array, *, interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """xdt: (B, H, nc, Q, P) dt-scaled inputs; da: (B, H, nc, Q) log-decays;
    b, c: (B, nc, Q, N) (single group, shared over heads).
    Returns (y_intra (B, H, nc, Q, P), states (B, H, nc, P, N))."""
    B, H, nc, Q, P = xdt.shape
    N = b.shape[-1]
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h, n: (i, h, n, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda i, h, n: (i, h, n, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, n: (i, n, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, h, n: (i, n, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda i, h, n: (i, h, n, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda i, h, n: (i, h, n, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, P, N), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
        name="ssd_intra_chunk",
    )(xdt, da, b, c)
    return y, st


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                    c: jax.Array, chunk: int,
                    init_state: Optional[jax.Array] = None,
                    interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array]:
    """Full SSD scan with the Pallas intra-chunk kernel; drop-in equivalent
    of :func:`repro.layers.ssm.ssd_chunked` (same signature/semantics)."""
    Bt, L, H, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    f32 = jnp.float32

    dtf = dt.astype(f32)
    da = (dtf * a.astype(f32)[None, None, :]).reshape(Bt, nc, chunk, H)
    da = jnp.moveaxis(da, -1, 1)                       # (Bt, H, nc, Q)
    xdt = (x.astype(f32) * dtf[..., None]).reshape(Bt, nc, chunk, H, P)
    xdt = jnp.moveaxis(xdt, 3, 1)                      # (Bt, H, nc, Q, P)
    bc = b.astype(f32).reshape(Bt, nc, chunk, N)
    cc = c.astype(f32).reshape(Bt, nc, chunk, N)

    y_intra, states = ssd_intra_chunk_pallas(xdt, da, bc, cc,
                                             interpret=interpret)

    # inter-chunk recurrence (XLA)
    chunk_decay = jnp.exp(jnp.sum(da, axis=-1))        # (Bt, H, nc)
    s0 = (init_state.astype(f32) if init_state is not None
          else jnp.zeros((Bt, H, P, N), f32))
    final, prev = jax.lax.scan(
        lambda cry, i: (cry * i[1][..., None, None] + i[0], cry),
        s0, (jnp.moveaxis(states, 2, 0), jnp.moveaxis(chunk_decay, 2, 0)))
    prev = jnp.moveaxis(prev, 0, 2)                    # (Bt, H, nc, P, N)
    decay_from_start = jnp.exp(jnp.cumsum(da, axis=-1))
    y_inter = jnp.einsum("bnlm,bhnl,bhnpm->bhnlp",
                         cc, decay_from_start, prev)
    y = (y_intra + y_inter)                            # (Bt, H, nc, Q, P)
    y = jnp.moveaxis(y, 1, 3).reshape(Bt, L, H, P)
    return y.astype(x.dtype), final

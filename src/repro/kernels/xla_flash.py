"""Blocked (flash-style) attention in pure XLA with a custom VJP.

Why this exists: the assigned shapes reach 524,288 tokens; a naive
softmax(QK^T)V materialises an O(L_q x L_k) logits tensor, which neither
fits HBM nor passes the dry-run memory analysis.  This implementation
streams K/V in blocks with an online-softmax accumulator (forward) and
recomputes blocks in the backward pass (no O(L^2) residuals) — the same
algorithm the Pallas TPU kernel (`repro.kernels.flash_attention`) uses
with explicit VMEM tiles; this module is its shape-polymorphic oracle and
the path the CPU dry-run lowers.

Masking is positional: callers pass integer ``q_pos``/``k_pos`` arrays.
``causal`` masks ``k_pos > q_pos``; ``window > 0`` additionally masks
``k_pos <= q_pos - window`` (sliding-window attention); invalid K slots
are expressed by setting their ``k_pos`` to ``INVALID_POS`` (never
attended under causal masking).  Fully-masked query rows return zeros.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38
INVALID_POS = jnp.iinfo(jnp.int32).max // 2


def _pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _mask_block(qp: jax.Array, kp: jax.Array, causal: bool,
                window: jax.Array) -> jax.Array:
    """window may be a traced int32 scalar; 0 disables the sliding window
    (so per-layer window patterns can ride through one lax.scan)."""
    m = kp[None, :] != INVALID_POS
    if causal:
        m = jnp.logical_and(m, kp[None, :] <= qp[:, None])
    weff = jnp.where(window > 0, window, jnp.int32(2**30))
    m = jnp.logical_and(m, kp[None, :] > qp[:, None] - weff)
    return m


# ---------------------------------------------------------------------------
# Single-(batched-)head forward / backward over flattened head-batch
# q: (N, Lq, D); k, v: (N, Lk, D); qp: (N, Lq); kp: (N, Lk)
# ---------------------------------------------------------------------------


def _block_mask(qpi, kpj, causal, window):
    """qpi (qb,) or (N, qb); kpj (kb,) or (N, kb) -> (qb, kb) or (N, qb, kb).

    SHARED positions (1-D) are the common case (training/prefill: every
    batch row has positions 0..L-1); keeping the mask head- and batch-free
    lets XLA hoist a few MB instead of tens of GB (EXPERIMENTS.md §Perf).
    """
    if qpi.ndim == 1:
        return _mask_block(qpi, kpj, causal, window)
    return jax.vmap(_mask_block, (0, 0, None, None))(qpi, kpj, causal,
                                                     window)


def _fwd(q, k, v, qp, kp, causal, window, softcap, qb, kb):
    N, Lq, D = q.shape
    Lk = k.shape[1]
    scale = D ** -0.5
    nq, nk = Lq // qb, Lk // kb
    f32 = jnp.float32
    shared = qp.ndim == 1

    qr = q.reshape(N, nq, qb, D)
    qpr = qp.reshape(nq, qb) if shared else qp.reshape(N, nq, qb)
    kr = k.reshape(N, nk, kb, D)
    vr = v.reshape(N, nk, kb, D)
    kpr = kp.reshape(nk, kb) if shared else kp.reshape(N, nk, kb)

    def q_block(carry, inp):
        qi, qpi = inp                 # (N, qb, D), (qb,)|(N, qb)

        def k_block(acc, kin):
            o, l, m = acc
            kj, vj, kpj = kin
            s = jnp.einsum("nqd,nkd->nqk", qi.astype(f32) * scale,
                           kj.astype(f32))
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            mask = _block_mask(qpi, kpj, causal, window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask, p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + jnp.einsum(
                "nqk,nkd->nqd", p, vj.astype(f32))
            return (o, l, m_new), None

        o0 = jnp.zeros((N, qb, D), f32)
        l0 = jnp.zeros((N, qb), f32)
        m0 = jnp.full((N, qb), NEG_INF, f32)
        (o, l, m), _ = jax.lax.scan(
            k_block, (o0, l0, m0),
            (jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0),
             kpr if shared else jnp.moveaxis(kpr, 1, 0)))
        o = o / (l[..., None] + 1e-30)
        lse = m + jnp.log(l + 1e-30)
        return carry, (o, lse)

    _, (o, lse) = jax.lax.scan(
        q_block, None,
        (jnp.moveaxis(qr, 1, 0), qpr if shared else jnp.moveaxis(qpr, 1, 0)))
    o = jnp.moveaxis(o, 0, 1).reshape(N, Lq, D)
    lse = jnp.moveaxis(lse, 0, 1).reshape(N, Lq)
    return o.astype(q.dtype), lse


def _bwd(q, k, v, qp, kp, o, lse, do, causal, window, softcap, qb, kb):
    N, Lq, D = q.shape
    Lk = k.shape[1]
    scale = D ** -0.5
    nq, nk = Lq // qb, Lk // kb
    f32 = jnp.float32
    shared = qp.ndim == 1

    qr = jnp.moveaxis(q.reshape(N, nq, qb, D), 1, 0)
    qpr = qp.reshape(nq, qb) if shared else \
        jnp.moveaxis(qp.reshape(N, nq, qb), 1, 0)
    dor = jnp.moveaxis(do.reshape(N, nq, qb, D), 1, 0).astype(f32)
    orr = jnp.moveaxis(o.reshape(N, nq, qb, D), 1, 0).astype(f32)
    lser = jnp.moveaxis(lse.reshape(N, nq, qb), 1, 0)
    delta = jnp.sum(dor * orr, axis=-1)                # (nq, N, qb)

    kr = jnp.moveaxis(k.reshape(N, nk, kb, D), 1, 0)
    vr = jnp.moveaxis(v.reshape(N, nk, kb, D), 1, 0)
    kpr = kp.reshape(nk, kb) if shared else \
        jnp.moveaxis(kp.reshape(N, nk, kb), 1, 0)

    def k_block(dq_full, kin):
        kj, vj, kpj = kin                              # (N, kb, D) …

        def q_block(acc, qin):
            dq_full, dkj, dvj = acc
            i, qi, qpi, doi, lsei, di = qin
            s = jnp.einsum("nqd,nkd->nqk", qi.astype(f32) * scale,
                           kj.astype(f32))
            if softcap > 0.0:
                t = jnp.tanh(s / softcap)
                s_capped = t * softcap
                dcap = 1.0 - t * t
            else:
                s_capped = s
                dcap = None
            mask = _block_mask(qpi, kpj, causal, window)
            p = jnp.exp(jnp.where(mask, s_capped, NEG_INF) -
                        lsei[..., None])
            p = jnp.where(mask, p, 0.0)
            dvj = dvj + jnp.einsum("nqk,nqd->nkd", p, doi)
            dp = jnp.einsum("nqd,nkd->nqk", doi, vj.astype(f32))
            ds = p * (dp - di[..., None])
            if dcap is not None:
                ds = ds * dcap
            dq_i = jnp.einsum("nqk,nkd->nqd", ds, kj.astype(f32)) * scale
            dkj = dkj + jnp.einsum("nqk,nqd->nkd", ds,
                                   qi.astype(f32)) * scale
            prev = jax.lax.dynamic_slice_in_dim(dq_full, i * qb, qb, axis=1)
            dq_full = jax.lax.dynamic_update_slice_in_dim(
                dq_full, prev + dq_i, i * qb, axis=1)
            return (dq_full, dkj, dvj), None

        dkj0 = jnp.zeros((N, kb, D), f32)
        dvj0 = jnp.zeros((N, kb, D), f32)
        (dq_full, dkj, dvj), _ = jax.lax.scan(
            q_block, (dq_full, dkj0, dvj0),
            (jnp.arange(nq), qr, qpr, dor, lser, delta))
        return dq_full, (dkj, dvj)

    dq0 = jnp.zeros((N, Lq, D), f32)
    dq, (dk, dv) = jax.lax.scan(k_block, dq0, (kr, vr, kpr))
    dk = jnp.moveaxis(dk, 0, 1).reshape(N, Lk, D)
    dv = jnp.moveaxis(dv, 0, 1).reshape(N, Lk, D)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------------
# Public multi-head GQA wrapper with custom VJP
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, k_pos: jax.Array,
                    window: jax.Array | int = 0,
                    causal: bool = True,
                    softcap: float = 0.0, q_block: int = 512,
                    k_block: int = 512) -> jax.Array:
    """Memory-O(L·block) attention.

    q: (B, Lq, H, D); k, v: (B, Lk, KV, D) with H % KV == 0;
    q_pos: (B, Lq) int32; k_pos: (B, Lk) int32 (INVALID_POS = masked slot).
    ``window`` may be a traced int32 scalar (0 = no sliding window).
    Returns (B, Lq, H, D).
    """
    o, _ = _flash_fwd_rule(q, k, v, q_pos, k_pos, window, causal, softcap,
                           q_block, k_block)
    return o


def _gqa_flatten(q, k, v, q_pos, k_pos):
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Lq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, -1, D)
    if q_pos.ndim == 1:               # shared positions: keep mask tiny
        return qf, kf, vf, q_pos, k_pos
    qpf = jnp.repeat(q_pos, H, axis=0).reshape(B * H, Lq)
    kpf = jnp.repeat(k_pos, H, axis=0).reshape(B * H, -1)
    return qf, kf, vf, qpf, kpf


def _flash_fwd_rule(q, k, v, q_pos, k_pos, window, causal, softcap,
                    q_block, k_block):
    B, Lq, H, D = q.shape
    Lk = k.shape[1]
    qb = min(q_block, Lq)
    kb = min(k_block, Lk)
    window = jnp.asarray(window, jnp.int32)
    qf, kf, vf, qpf, kpf = _gqa_flatten(q, k, v, q_pos, k_pos)
    # pad to block multiples; padded K slots get INVALID_POS
    qf = _pad_to(qf, qb, 1)
    qpf = _pad_to(qpf, qb, qpf.ndim - 1)
    kf = _pad_to(kf, kb, 1)
    vf = _pad_to(vf, kb, 1)
    kpf = _pad_to(kpf, kb, kpf.ndim - 1, value=INVALID_POS)
    of, lse = _fwd(qf, kf, vf, qpf, kpf, causal, window, softcap, qb, kb)
    o = of[:, :Lq].reshape(B, H, Lq, D).transpose(0, 2, 1, 3)
    return o, (q, k, v, q_pos, k_pos, window, o, lse[:, :Lq])


def _flash_bwd_rule(causal, softcap, q_block, k_block, res, do):
    q, k, v, q_pos, k_pos, window, o, lse = res
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    Lk = k.shape[1]
    qb = min(q_block, Lq)
    kb = min(k_block, Lk)
    qf, kf, vf, qpf, kpf = _gqa_flatten(q, k, v, q_pos, k_pos)
    dof = do.transpose(0, 2, 1, 3).reshape(B * H, Lq, D)
    of = o.transpose(0, 2, 1, 3).reshape(B * H, Lq, D)
    qf = _pad_to(qf, qb, 1)
    qpf = _pad_to(qpf, qb, qpf.ndim - 1)
    dof = _pad_to(dof, qb, 1)
    of = _pad_to(of, qb, 1)
    lsef = _pad_to(lse, qb, 1)
    kf = _pad_to(kf, kb, 1)
    vf = _pad_to(vf, kb, 1)
    kpf = _pad_to(kpf, kb, kpf.ndim - 1, value=INVALID_POS)
    dqf, dkf, dvf = _bwd(qf, kf, vf, qpf, kpf, of, lsef, dof,
                         causal, window, softcap, qb, kb)
    dq = dqf[:, :Lq].reshape(B, H, Lq, D).transpose(0, 2, 1, 3)
    dk = dkf[:, :Lk].reshape(B, KV, G, Lk, D).sum(axis=2)
    dk = dk.transpose(0, 2, 1, 3)
    dv = dvf[:, :Lk].reshape(B, KV, G, Lk, D).sum(axis=2)
    dv = dv.transpose(0, 2, 1, 3)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)

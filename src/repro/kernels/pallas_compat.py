"""Small jax-version compatibility shims for the Pallas TPU kernels.

The TPU compiler-params class was renamed upstream
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); resolving it
here keeps the kernels importable (and their interpret-mode parity tests
runnable on CPU) across the jax versions this repo meets in CI and in the
container images.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

"""Pallas TPU kernel for the O(1) cache-hit decode step (paper Eq. 5).

One new query token attends over a *constant-size* KV buffer — the
compressed context (W_oh slots) or the generation window (W_og slots).
Because TConstFormer bounds both, the ENTIRE working set of a decode step
fits VMEM by construction: q (G x D), K/V (S x D) with S = W_oh <= 512.
This kernel is the TPU restatement of the paper's core claim — the decode
step never touches an O(N) buffer, so it cannot be HBM-bandwidth bound in
sequence length.

Grid: (B, KV) — fully parallel; no sequential dimension, no scratch.
The QK^T contraction, masked softmax, and PV contraction are fused in one
kernel invocation per (batch, kv-head).

Layout-native extensions (DecodeAPI v3, "KVView"):

* **int8 KV** — when ``k_scale``/``v_scale`` are given, ``k``/``v`` are
  int8 with per-vector float32 scales and the dequantisation is FUSED
  into the QK / PV loops: the kernel reads 1 byte per element from HBM
  and multiplies by the scale inside VMEM, so the quantized layout's 4x
  byte saving is realised on the hot path instead of being paid back by
  a dense dequantised materialisation.
* **sliding window** — positions ``<= valid_len - 1`` but within the last
  ``window`` slots are attended (the dense-LM per-layer local-attention
  pattern), matching ``layers.attention.decode_attend``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -2.3819763e38


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, *rest, softcap: float,
                   window: int, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref = rest
    else:
        (o_ref,) = rest
    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (S, D)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (S, D)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)    # (S, 1) scales
        v = v * vs_ref[0, :, 0].astype(jnp.float32)
    vl = vl_ref[0, 0]                                  # scalar int32

    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (G, S)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    slot = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = slot < vl
    if window > 0:
        mask = jnp.logical_and(mask, slot >= vl - window)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = (o / (l + 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid_len: jax.Array, *, softcap: float = 0.0,
                            window: int = 0,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one token per sequence; k/v: (B, S, KV, D);
    valid_len: (B,) — slots [0, valid_len) attended (``window`` > 0
    additionally limits attention to the last ``window`` of them).
    int8 KV: pass ``k_scale``/``v_scale`` (B, S, KV, 1) float32 and int8
    ``k``/``v`` — dequant is fused in-kernel.  Returns (B, H, D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    vl = valid_len.reshape(B, 1).astype(jnp.int32)
    quant = k_scale is not None

    kernel = functools.partial(_decode_kernel, softcap=softcap,
                               window=window, quant=quant)
    in_specs = [
        pl.BlockSpec((1, 1), lambda b, h: (b, 0)),            # valid_len
        pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),  # q
        pl.BlockSpec((1, S, 1, D), lambda b, h: (b, 0, h, 0)),  # k
        pl.BlockSpec((1, S, 1, D), lambda b, h: (b, 0, h, 0)),  # v
    ]
    args = [vl, qg, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, S, 1, 1), lambda b, h: (b, 0, h, 0)),  # kscale
            pl.BlockSpec((1, S, 1, 1), lambda b, h: (b, 0, h, 0)),  # vscale
        ]
        args += [k_scale, v_scale]
    out = pl.pallas_call(
        kernel,
        grid=(B, KV),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), jnp.float32 if quant
                                       else q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="tconst_decode_attention",
    )(*args)
    return out.reshape(B, H, D).astype(q.dtype)

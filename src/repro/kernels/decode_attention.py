"""Pallas TPU kernel for the O(1) cache-hit decode step (paper Eq. 5).

One new query token attends over a *constant-size* KV buffer — the
compressed context (W_oh slots) or the generation window (W_og slots).
Because TConstFormer bounds both, the ENTIRE working set of a decode step
fits VMEM by construction: q (G x D), K/V (S x D) with S = W_oh <= 512.
This kernel is the TPU restatement of the paper's core claim — the decode
step never touches an O(N) buffer, so it cannot be HBM-bandwidth bound in
sequence length.

Grid: (B, KV) — fully parallel; no sequential dimension, no scratch.
The QK^T contraction, masked softmax, and PV contraction are fused in one
kernel invocation per (batch, kv-head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, *, softcap: float):
    q = q_ref[0, 0].astype(jnp.float32)                # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)             # (S, D)
    v = v_ref[0, :, 0].astype(jnp.float32)             # (S, D)
    vl = vl_ref[0, 0]                                  # scalar int32

    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (G, S)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    slot = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = slot < vl
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = (o / (l + 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid_len: jax.Array, *, softcap: float = 0.0,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one token per sequence; k/v: (B, S, KV, D);
    valid_len: (B,) — slots [0, valid_len) attended.  Returns (B, H, D)."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    vl = valid_len.reshape(B, 1).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, softcap=softcap)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),            # valid_len
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, S, 1, D), lambda b, h: (b, 0, h, 0)),  # k
            pl.BlockSpec((1, S, 1, D), lambda b, h: (b, 0, h, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="tconst_decode_attention",
    )(vl, qg, k, v)
    return out.reshape(B, H, D)

"""Layout-native paged decode attention: the kernel walks the page table.

vLLM-style paged KV ("Attention Once Is All You Need" line of work): the
physical cache is a shared pool of fixed-size pages plus a per-slot int32
page table, and the decode kernel consumes that representation DIRECTLY —
one page = one grid block, with the page table as a scalar-prefetch
operand so each block's DMA source address is computed from
``page_table[b, j]`` before the block body runs
(``pltpu.PrefetchScalarGridSpec``).  Nothing ever materialises the dense
``slots x max_len`` logical view; a decode step touches exactly the pages
the slot owns.

Two implementations, one contract (see ``repro.kernels.ops.paged_decode``):

* :func:`paged_decode_attention_pallas` — TPU kernel.  Grid
  ``(B, KV, pages_per_slot)`` with the page dimension sequential
  ("arbitrary"): per (batch, kv-head) the kernel runs an online-softmax
  accumulation over the slot's pages in VMEM scratch (running max /
  denominator / output).  int8 pools fuse the per-vector dequantisation
  into the QK and PV contractions (1 byte/element off HBM).
* :func:`paged_decode_attention_xla` — the CPU / interpret fallback: a
  ``lax.scan`` over pages, each iteration gathering ONE page per slot
  (``(B, page, KV, D)`` working set).  It uses a two-pass exact-max
  softmax so its output matches the dense oracle to float-associativity
  noise — the parity suite compares both against ``DecodeState.merged``.

Both accept logical ``valid_len`` (slots ``[0, valid_len)`` attended) and
an optional sliding ``window`` (the dense-LM local-attention layers), so
they are drop-in for every paged field: the dense-LM ``k/v``, the enc-dec
decoder KV and TLinFormer's per-block history KV.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -2.3819763e38


# ---------------------------------------------------------------------------
# Pallas TPU kernel: one page = one block, table walked via scalar prefetch
# ---------------------------------------------------------------------------


def _paged_kernel(pt_ref, vl_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                  page: int, softcap: float, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (page, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)     # (page, 1) scales
        v = v * vs_ref[0, :, 0].astype(jnp.float32)

    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    slot = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    vl = vl_ref[b]
    win = win_ref[0]
    weff = jnp.where(win > 0, win, jnp.int32(2 ** 30))
    mask = jnp.logical_and(slot < vl, slot >= vl - weff)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       (l_ref[...] + 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(
        q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
        page_table: jax.Array, valid_len: jax.Array, *,
        softcap: float = 0.0, window: "int | jax.Array" = 0,
        k_scale: Optional[jax.Array] = None,
        v_scale: Optional[jax.Array] = None,
        interpret: bool = False) -> jax.Array:
    """q: (B, H, D) one token per slot; pool_k/pool_v: (pool+1, page, KV, D)
    shared page pools (last page = trash, masked off by ``valid_len``);
    page_table: (B, pages_per_slot) int32; valid_len: (B,) — logical slots
    [0, valid_len) attended.  int8 pools: pass (pool+1, page, KV, 1) f32
    ``k_scale``/``v_scale`` (dequant fused in-kernel).  Returns (B, H, D)."""
    B, H, D = q.shape
    page, KV = pool_k.shape[1], pool_k.shape[2]
    pps = page_table.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, D)
    vl = valid_len.astype(jnp.int32)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    quant = k_scale is not None

    kernel = functools.partial(_paged_kernel, page=page, softcap=softcap,
                               quant=quant)
    # index maps receive (b, h, j, *scalar_prefetch_refs): the page-table
    # ref picks the physical page for grid step (b, j) — the "in-kernel
    # page-table walk".
    in_specs = [
        pl.BlockSpec((1, 1, G, D), lambda b, h, j, pt, vl, w: (b, h, 0, 0)),
        pl.BlockSpec((1, page, 1, D),
                     lambda b, h, j, pt, vl, w: (pt[b, j], 0, h, 0)),
        pl.BlockSpec((1, page, 1, D),
                     lambda b, h, j, pt, vl, w: (pt[b, j], 0, h, 0)),
    ]
    args = [qg, pool_k, pool_v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, page, 1, 1),
                         lambda b, h, j, pt, vl, w: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page, 1, 1),
                         lambda b, h, j, pt, vl, w: (pt[b, j], 0, h, 0)),
        ]
        args += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, pt, vl, w: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max
            pltpu.VMEM((G, 1), jnp.float32),     # running denominator
            pltpu.VMEM((G, D), jnp.float32),     # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_decode_attention",
    )(page_table.astype(jnp.int32), vl, win, *args)
    return out.reshape(B, H, D)


# ---------------------------------------------------------------------------
# XLA fallback: scan over pages, (B, page, KV, D) working set, exact max
# ---------------------------------------------------------------------------


def paged_decode_attention_xla(
        q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
        page_table: jax.Array, valid_len: jax.Array, *,
        softcap: float = 0.0, window: "int | jax.Array" = 0,
        k_scale: Optional[jax.Array] = None,
        v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Same contract as the Pallas kernel, in plain XLA: a page-at-a-time
    ``lax.scan`` whose largest intermediate is one (B, page, KV, D) gather
    — never the dense (B, max_len, KV, D) logical view.  Two passes with
    an exact global max (max is order-independent in fp) keep the output
    within float-associativity noise of the dense-softmax oracle."""
    B, H, D = q.shape
    page, KV = pool_k.shape[1], pool_k.shape[2]
    pps = page_table.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32) * (D ** -0.5)
    vl = valid_len.astype(jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    weff = jnp.where(win > 0, win, jnp.int32(2 ** 30))
    ptT = jnp.moveaxis(page_table.astype(jnp.int32), 1, 0)   # (pps, B)
    page_ids = jnp.arange(pps, dtype=jnp.int32)

    def logits(j, ptj):
        k = jnp.take(pool_k, ptj, axis=0)                # (B, page, KV, D)
        if k_scale is not None:
            k = k.astype(jnp.float32) * jnp.take(k_scale, ptj, axis=0)
        s = jnp.einsum("bkgd,bpkd->bkgp", qg, k.astype(jnp.float32))
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        slot = j * page + jnp.arange(page, dtype=jnp.int32)
        mask = jnp.logical_and(slot[None] < vl[:, None],
                               slot[None] >= (vl - weff)[:, None])  # (B, p)
        return jnp.where(mask[:, None, None, :], s, NEG_INF), mask

    def max_body(m, xs):
        s, _ = logits(*xs)
        return jnp.maximum(m, jnp.max(s, axis=-1)), None

    m, _ = jax.lax.scan(max_body, jnp.full((B, KV, G), NEG_INF, jnp.float32),
                        (page_ids, ptT))

    def acc_body(carry, xs):
        l, acc = carry
        j, ptj = xs
        s, mask = logits(j, ptj)
        e = jnp.exp(s - m[..., None]) * mask[:, None, None, :]
        v = jnp.take(pool_v, ptj, axis=0)
        if v_scale is not None:
            v = v.astype(jnp.float32) * jnp.take(v_scale, ptj, axis=0)
        acc = acc + jnp.einsum("bkgp,bpkd->bkgd", e, v.astype(jnp.float32))
        return (l + jnp.sum(e, axis=-1), acc), None

    (l, acc), _ = jax.lax.scan(
        acc_body,
        (jnp.zeros((B, KV, G), jnp.float32),
         jnp.zeros((B, KV, G, D), jnp.float32)),
        (page_ids, ptT))
    o = acc / (l[..., None] + 1e-30)
    return o.reshape(B, H, D).astype(q.dtype)

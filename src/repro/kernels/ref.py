"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematically transparent O(L^2)-memory reference the
kernels are asserted against (``tests/test_kernels.py`` sweeps shapes and
dtypes).  They are deliberately naive — correctness over efficiency.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38
INVALID_POS = jnp.iinfo(jnp.int32).max // 2


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_pos: jax.Array, k_pos: jax.Array,
                  window: int = 0, causal: bool = True,
                  softcap: float = 0.0) -> jax.Array:
    """Naive GQA attention.  q (B, Lq, H, D); k/v (B, Lk, KV, D);
    q_pos (B, Lq); k_pos (B, Lk) with INVALID_POS marking dead slots."""
    B, Lq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, Lq, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("blkgd,bskd->bklgs", qg * scale, k.astype(jnp.float32))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    mask = k_pos[:, None, :] != INVALID_POS
    if causal:
        mask = jnp.logical_and(mask, k_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        mask = jnp.logical_and(mask,
                               k_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, :, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mx) * mask[:, None, :, None, :]
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)
    o = jnp.einsum("bklgs,bskd->blkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Lq, H, D).astype(q.dtype)


def decode_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, softcap: float = 0.0
                     ) -> jax.Array:
    """Single-token decode oracle.  q (B, H, D); k/v (B, S, KV, D);
    valid_len (B,): slots [0, valid_len) are attended."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg * scale, k.astype(jnp.float32))
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(S)[None] < valid_len[:, None]          # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mx) * valid[:, None, None, :]
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def ssd_chunk_reference(x: jax.Array, da: jax.Array, b: jax.Array,
                        c: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD oracle for ONE chunk.

    x: (Q, P) inputs already scaled by dt; da: (Q,) log-decays;
    b, c: (Q, N).  Returns (y_intra (Q, P), chunk_state (P, N)).
    """
    Q = x.shape[0]
    cs = jnp.cumsum(da)
    diff = cs[:, None] - cs[None, :]                          # (Q, Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("ln,sn->ls", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    y = jnp.einsum("ls,ls,sp->lp", scores, decay, x.astype(jnp.float32))
    decay_to_end = jnp.exp(cs[-1] - cs)                       # (Q,)
    state = jnp.einsum("s,sn,sp->pn", decay_to_end,
                       b.astype(jnp.float32), x.astype(jnp.float32))
    return y.astype(x.dtype), state

"""Pallas TPU flash-attention kernel (forward).

TPU adaptation of the paper's cache-miss hot spot — the context-compression
cross-attention (W_oh queries over the full history) — and of ordinary
causal/sliding self-attention.  The GPU-oriented description in the paper
("memory copy bound torch.cat decode") becomes, on TPU, an HBM->VMEM
streaming problem: K/V are streamed through VMEM in MXU-aligned
``block_k`` tiles while an online-softmax accumulator lives in VMEM
scratch across the sequential ``nk`` grid dimension.

Grid: ``(BH, nq, nk)`` — (batch x heads) and query blocks are parallel;
the key-block dimension is sequential ("arbitrary") and owns the scratch
accumulator.  Block shapes are multiples of 128 in the lane dimension so
the ``s = q @ k^T`` and ``p @ v`` contractions map onto the 128x128 MXU.

The backward pass reuses the XLA blocked implementation
(``repro.kernels.xla_flash``) via ``jax.custom_vjp`` in ``ops.py`` — on
real TPUs one would add the dual Pallas bwd kernel; the fwd kernel is the
inference-critical path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -2.3819763e38
INVALID_POS = jnp.iinfo(jnp.int32).max // 2


def _flash_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, causal: bool, window: int,
                  softcap: float, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # (qb, D)
    k = k_ref[0].astype(jnp.float32)                   # (kb, D)
    v = v_ref[0].astype(jnp.float32)                   # (kb, D)
    qp = qp_ref[0]                                     # (qb,)
    kp = kp_ref[0]                                     # (kb,)

    scale = q.shape[-1] ** -0.5
    s = jax.lax.dot_general(q * scale, k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (qb, kb)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    mask = kp[None, :] != INVALID_POS
    if causal:
        mask = jnp.logical_and(mask, kp[None, :] <= qp[:, None])
    if window > 0:
        mask = jnp.logical_and(mask, kp[None, :] > qp[:, None] - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / (l_scr[...] + 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd_pallas(
        q: jax.Array, k: jax.Array, v: jax.Array,
        q_pos: jax.Array, k_pos: jax.Array, *,
        causal: bool = True, window: int = 0, softcap: float = 0.0,
        block_q: int = 256, block_k: int = 512,
        interpret: bool = False) -> jax.Array:
    """q: (B, Lq, H, D); k/v: (B, Lk, KV, D); positions (B, Lq)/(B, Lk).

    Static ``window`` (the Pallas kernel specialises per layer type; the
    dynamic-window path is served by ``xla_flash``).  Returns (B, Lq, H, D).
    """
    B, Lq, H, D = q.shape
    Lk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb = min(block_q, Lq)
    kb = min(block_k, Lk)
    assert Lq % qb == 0 and Lk % kb == 0, (Lq, qb, Lk, kb)
    nq, nk = Lq // qb, Lk // kb

    # flatten (B, H) and broadcast K/V over the GQA group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Lq, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Lk, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Lk, D)
    qpf = jnp.repeat(q_pos, H, axis=0)
    kpf = jnp.repeat(k_pos, H, axis=0)

    kernel = functools.partial(_flash_kernel, causal=causal, window=window,
                               softcap=softcap, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb), lambda b, i, j: (b, i)),        # q_pos
            pl.BlockSpec((1, kb), lambda b, i, j: (b, j)),        # k_pos
            pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),  # q
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),  # k
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),      # running max
            pltpu.VMEM((qb, 1), jnp.float32),      # running denom
            pltpu.VMEM((qb, D), jnp.float32),      # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(qpf, kpf, qf, kf, vf)
    return out.reshape(B, H, Lq, D).transpose(0, 2, 1, 3)

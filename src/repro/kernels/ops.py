"""Jit'd dispatch wrappers for the Pallas kernels.

Routing policy
--------------
* On TPU (``jax.default_backend() == "tpu"``): Pallas kernels, compiled.
* Elsewhere (this CPU container, and the dry-run which lowers pure XLA):
  - ``repro.kernels.xla_flash`` for big attention (same blocked algorithm,
    plain XLA ops, differentiable);
  - the pure-jnp references for small shapes.
* ``repro.runtime.flags.use_pallas`` + ``pallas_interpret`` force the
  Pallas path in interpret mode (used by the kernel test sweeps).

``flash`` is differentiable everywhere: on the Pallas path the forward
runs the TPU kernel and the backward falls back to the XLA blocked
implementation via ``jax.custom_vjp`` (the production bwd kernel is the
listed follow-up in DESIGN.md).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro import runtime
from repro.kernels import ref as REF
from repro.kernels import xla_flash as XF
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_fwd_pallas
from repro.kernels.paged_decode_attention import (
    paged_decode_attention_pallas, paged_decode_attention_xla)
from repro.kernels.ssd_scan import ssd_scan_pallas


def _pallas_enabled() -> bool:
    return runtime.flags.use_pallas or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return runtime.flags.pallas_interpret and jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Decode-mesh scope (mesh-native serving)
#
# The DecodeAPI step/sync/chunk bodies trace inside ``decode_mesh_scope``;
# while the scope is active the decode and prefill-chunk attention below
# shard_map themselves over the mesh: query/output head dims and the KV-head
# dim of the caches split over ``model`` (each shard computes its local
# head slice — per-head attention is embarrassingly parallel, so the body
# needs NO collective; the single psum for the output projection is the
# all-reduce GSPMD inserts at the model-sharded ``wo`` contraction just
# outside), the slot/batch dim splits over the data axes, and the paged
# pool rides in REPLICATED over data + sharded over model, so a sharded
# step never all-gathers the KV pool.  The Pallas page-walk kernel runs
# per-shard on its local head slice; the XLA fallback is unchanged —
# both see ordinary smaller arrays inside the shard_map body.
# ---------------------------------------------------------------------------

_DECODE_MESH: list = [None]


@contextlib.contextmanager
def decode_mesh_scope(mesh):
    """Trace-time scope; accepts None, a jax Mesh, or anything with a
    ``.mesh`` attribute (e.g. ``repro.sharding.rules.MeshContext``)."""
    _DECODE_MESH.append(getattr(mesh, "mesh", mesh))
    try:
        yield
    finally:
        _DECODE_MESH.pop()


def _decode_mesh() -> Optional[Mesh]:
    return _DECODE_MESH[-1]


def _mesh_axes(mesh: Mesh, *, batch: int, heads: Tuple[int, ...]
               ) -> Tuple[Any, Optional[str]]:
    """(data spec entry for the batch dim, model spec entry for head
    dims) — None where the respective sizes don't divide, so partially
    applicable meshes degrade per-axis instead of bailing out."""
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    db = None
    if dsize > 1 and batch % dsize == 0 and batch >= dsize:
        db = daxes if len(daxes) > 1 else daxes[0]
    mb = None
    msize = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if msize > 1 and all(h % msize == 0 and h >= msize for h in heads):
        mb = "model"
    return db, mb


def _shard_mapped(inner, mesh: Mesh, in_specs, out_specs):
    """shard_map with the conventions used here: dict-pytree operands,
    replication checking off (per-shard valid_len/page tables are
    intentionally replicated inside a data shard)."""
    return shard_map(inner, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window, softcap):
    return flash_attention_fwd_pallas(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        softcap=softcap, interpret=_interpret())


def _fp_fwd(q, k, v, q_pos, k_pos, causal, window, softcap):
    o = _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window, softcap)
    return o, (q, k, v, q_pos, k_pos)


def _fp_bwd(causal, window, softcap, res, do):
    q, k, v, q_pos, k_pos = res
    # backward via the (differentiable) XLA blocked implementation
    _, vjp = jax.vjp(
        lambda q_, k_, v_: XF.flash_attention(
            q_, k_, v_, q_pos, k_pos, window, causal, softcap, 256, 512),
        q, k, v)
    dq, dk, dv = vjp(do)
    return dq, dk, dv, None, None


_flash_pallas_diff.defvjp(_fp_fwd, _fp_bwd)


def flash(q: jax.Array, k: jax.Array, v: jax.Array,
          q_pos: jax.Array, k_pos: jax.Array,
          window: "int | jax.Array" = 0, causal: bool = True,
          softcap: float = 0.0) -> jax.Array:
    """Dispatching flash attention (see module docstring)."""
    static_window = isinstance(window, int)
    if _pallas_enabled() and static_window and \
            q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
        return _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window,
                                  softcap)
    return XF.flash_attention(q, k, v, q_pos, k_pos, window, causal,
                              softcap, 512, 512)


# ---------------------------------------------------------------------------
# Chunked-prefill attention (admission path)
# ---------------------------------------------------------------------------

# route a chunk's score matrix through the blocked flash path above this
# many C x S elements (below it the masked reference sdpa is cheaper)
PREFILL_CHUNK_FLASH_ELEMS = 1 << 22


def _prefill_chunk_attention_impl(q, k, v, q_pos, k_pos, window, softcap):
    if q.shape[1] * k.shape[1] >= PREFILL_CHUNK_FLASH_ELEMS:
        return flash(q, k, v, q_pos, k_pos, window, True, softcap)
    from repro.layers.attention import make_mask, sdpa
    mask = make_mask(q_pos, k_pos, "sliding", window)
    return sdpa(q, k, v, mask, softcap)


def prefill_chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            q_pos: jax.Array, k_pos: jax.Array,
                            window: "int | jax.Array" = 0,
                            softcap: float = 0.0) -> jax.Array:
    """One prefill chunk's C queries against the slot's row cache.

    q: (B, C, H, D); k/v: (B, S, KV, D) — the row cache with positions
    [0, start + C) written (resident prefix + earlier chunks + this
    chunk).  Garbage beyond is causally dead: every unwritten slot's
    position exceeds every query's.  Causal + optional sliding window
    (``window`` may be a traced per-layer scalar).  Large score matrices
    route through the blocked flash path (Pallas when enabled); small
    shapes use the masked reference sdpa — numerically interchangeable.
    Under a decode-mesh scope the heads split over ``model`` via
    shard_map (chunk rows are batch-1, so the data axes don't apply).
    """
    mesh = _decode_mesh()
    if mesh is not None:
        db, mb = _mesh_axes(mesh, batch=q.shape[0],
                            heads=(q.shape[2], k.shape[2]))
        if db is not None or mb is not None:
            def _pos_spec(p):
                b = db if (p.ndim >= 2 and p.shape[0] == q.shape[0]) \
                    else None
                return P(*((b,) + (None,) * (p.ndim - 1)))
            operands: Dict[str, Any] = dict(q=q, k=k, v=v, q_pos=q_pos,
                                            k_pos=k_pos)
            specs: Dict[str, P] = dict(
                q=P(db, None, mb, None), k=P(db, None, mb, None),
                v=P(db, None, mb, None), q_pos=_pos_spec(q_pos),
                k_pos=_pos_spec(k_pos))
            static_window = isinstance(window, int)
            if not static_window:
                operands["window"] = jnp.asarray(window)
                specs["window"] = P()

            def inner(o):
                w = window if static_window else o["window"]
                return _prefill_chunk_attention_impl(
                    o["q"], o["k"], o["v"], o["q_pos"], o["k_pos"], w,
                    softcap)

            return _shard_mapped(inner, mesh, (specs,),
                                 P(db, None, mb, None))(operands)
    return _prefill_chunk_attention_impl(q, k, v, q_pos, k_pos, window,
                                         softcap)


# ---------------------------------------------------------------------------
# Decode attention (O(1) cache-hit step)
# ---------------------------------------------------------------------------


def _decode_attend_kv_impl(q, k, v, valid_len, softcap):
    if _pallas_enabled() and q.shape[-1] % 8 == 0:
        return decode_attention_pallas(q, k, v, valid_len, softcap=softcap,
                                       interpret=_interpret())
    return REF.decode_reference(q, k, v, valid_len, softcap=softcap)


def decode_attend_kv(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, softcap: float = 0.0
                     ) -> jax.Array:
    """q: (B, H, D); k/v: (B, S, KV, D); valid_len (B,).  Under a
    decode-mesh scope: slots over data, heads over model (shard_map)."""
    mesh = _decode_mesh()
    if mesh is not None:
        db, mb = _mesh_axes(mesh, batch=q.shape[0],
                            heads=(q.shape[1], k.shape[2]))
        if db is not None or mb is not None:
            inner = functools.partial(_decode_attend_kv_impl,
                                      softcap=softcap)
            return _shard_mapped(
                inner, mesh,
                (P(db, mb, None), P(db, None, mb, None),
                 P(db, None, mb, None), P(db)),
                P(db, mb, None))(q, k, v, valid_len)
    return _decode_attend_kv_impl(q, k, v, valid_len, softcap)


def _int8_decode_fused_impl(q, kq, vq, k_scale, v_scale, valid_len,
                            softcap, window):
    return decode_attention_pallas(
        q, kq, vq, valid_len, softcap=softcap, window=window,
        k_scale=k_scale, v_scale=v_scale, interpret=_interpret())


def int8_decode_fused(q: jax.Array, kq: jax.Array, vq: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array,
                      valid_len: jax.Array, softcap: float = 0.0,
                      window: int = 0) -> jax.Array:
    """Fused int8 decode: dequant happens inside the QK/AV loops (1 HBM
    byte per element).  Caller checks :func:`int8_fused_available`.
    Under a decode-mesh scope the int8 pools shard like their parents
    (KV heads over model); the (..., 1) scale dims stay replicated."""
    mesh = _decode_mesh()
    if mesh is not None:
        db, mb = _mesh_axes(mesh, batch=q.shape[0],
                            heads=(q.shape[1], kq.shape[2]))
        if db is not None or mb is not None:
            inner = functools.partial(_int8_decode_fused_impl,
                                      softcap=softcap, window=window)
            kv_spec = P(db, None, mb, None)
            return _shard_mapped(
                inner, mesh,
                (P(db, mb, None), kv_spec, kv_spec, kv_spec, kv_spec,
                 P(db)),
                P(db, mb, None))(q, kq, vq, k_scale, v_scale, valid_len)
    return _int8_decode_fused_impl(q, kq, vq, k_scale, v_scale, valid_len,
                                   softcap, window)


def int8_fused_available(window) -> bool:
    """The fused int8 kernel needs the Pallas path and a STATIC window
    (it is baked into the kernel); traced per-layer windows fall back to
    the dequantise-then-attend XLA path."""
    return _pallas_enabled() and isinstance(window, int)


# ---------------------------------------------------------------------------
# Paged decode attention (in-kernel page-table walk)
# ---------------------------------------------------------------------------


def _paged_decode_impl(q, pool_k, pool_v, page_table, valid_len, *,
                       softcap, window, k_scale, v_scale):
    if _pallas_enabled():
        return paged_decode_attention_pallas(
            q, pool_k, pool_v, page_table, valid_len, softcap=softcap,
            window=window, k_scale=k_scale, v_scale=v_scale,
            interpret=_interpret())
    return paged_decode_attention_xla(
        q, pool_k, pool_v, page_table, valid_len, softcap=softcap,
        window=window, k_scale=k_scale, v_scale=v_scale)


def paged_decode(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                 page_table: jax.Array, valid_len: jax.Array, *,
                 softcap: float = 0.0, window: "int | jax.Array" = 0,
                 k_scale=None, v_scale=None) -> jax.Array:
    """Layout-native paged decode attention: Pallas page-table-walk
    kernel on the Pallas path (compiled on TPU, interpret elsewhere),
    page-at-a-time XLA scan otherwise.  Neither materialises the dense
    (B, max_len, KV, D) logical view.

    Under a decode-mesh scope the step runs inside shard_map: queries
    split (slots over data, heads over model) and each shard walks the
    SAME page table over its LOCAL (pool, page, KV/shards, D) pool
    slice — the pool's page axis stays whole per shard (any slot may
    own any page), so no all-gather of the pool ever appears."""
    mesh = _decode_mesh()
    if mesh is not None:
        db, mb = _mesh_axes(mesh, batch=q.shape[0],
                            heads=(q.shape[1], pool_k.shape[-2]))
        if db is not None or mb is not None:
            pool_spec = P(None, None, mb, None)
            operands: Dict[str, Any] = dict(
                q=q, pool_k=pool_k, pool_v=pool_v, page_table=page_table,
                valid_len=valid_len)
            specs: Dict[str, P] = dict(
                q=P(db, mb, None), pool_k=pool_spec, pool_v=pool_spec,
                page_table=P(db, None), valid_len=P(db))
            static_window = isinstance(window, int)
            if not static_window:
                operands["window"] = jnp.asarray(window)
                specs["window"] = P()
            if k_scale is not None:
                operands["k_scale"] = k_scale
                operands["v_scale"] = v_scale
                specs["k_scale"] = pool_spec
                specs["v_scale"] = pool_spec

            def inner(o):
                return _paged_decode_impl(
                    o["q"], o["pool_k"], o["pool_v"], o["page_table"],
                    o["valid_len"], softcap=softcap,
                    window=window if static_window else o["window"],
                    k_scale=o.get("k_scale"), v_scale=o.get("v_scale"))

            return _shard_mapped(inner, mesh, (specs,),
                                 P(db, mb, None))(operands)
    return _paged_decode_impl(q, pool_k, pool_v, page_table, valid_len,
                              softcap=softcap, window=window,
                              k_scale=k_scale, v_scale=v_scale)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, a, b, c, chunk, init_state=None):
    if _pallas_enabled():
        return ssd_scan_pallas(x, dt, a, b, c, chunk, init_state,
                               interpret=_interpret())
    from repro.layers.ssm import ssd_chunked
    return ssd_chunked(x, dt, a, b, c, chunk, init_state)

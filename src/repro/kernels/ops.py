"""Jit'd dispatch wrappers for the Pallas kernels.

Routing policy
--------------
* On TPU (``jax.default_backend() == "tpu"``): Pallas kernels, compiled.
* Elsewhere (this CPU container, and the dry-run which lowers pure XLA):
  - ``repro.kernels.xla_flash`` for big attention (same blocked algorithm,
    plain XLA ops, differentiable);
  - the pure-jnp references for small shapes.
* ``repro.runtime.flags.use_pallas`` + ``pallas_interpret`` force the
  Pallas path in interpret mode (used by the kernel test sweeps).

``flash`` is differentiable everywhere: on the Pallas path the forward
runs the TPU kernel and the backward falls back to the XLA blocked
implementation via ``jax.custom_vjp`` (the production bwd kernel is the
listed follow-up in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import runtime
from repro.kernels import ref as REF
from repro.kernels import xla_flash as XF
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_fwd_pallas
from repro.kernels.paged_decode_attention import (
    paged_decode_attention_pallas, paged_decode_attention_xla)
from repro.kernels.ssd_scan import ssd_scan_pallas


def _pallas_enabled() -> bool:
    return runtime.flags.use_pallas or jax.default_backend() == "tpu"


def _interpret() -> bool:
    return runtime.flags.pallas_interpret and jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window, softcap):
    return flash_attention_fwd_pallas(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        softcap=softcap, interpret=_interpret())


def _fp_fwd(q, k, v, q_pos, k_pos, causal, window, softcap):
    o = _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window, softcap)
    return o, (q, k, v, q_pos, k_pos)


def _fp_bwd(causal, window, softcap, res, do):
    q, k, v, q_pos, k_pos = res
    # backward via the (differentiable) XLA blocked implementation
    _, vjp = jax.vjp(
        lambda q_, k_, v_: XF.flash_attention(
            q_, k_, v_, q_pos, k_pos, window, causal, softcap, 256, 512),
        q, k, v)
    dq, dk, dv = vjp(do)
    return dq, dk, dv, None, None


_flash_pallas_diff.defvjp(_fp_fwd, _fp_bwd)


def flash(q: jax.Array, k: jax.Array, v: jax.Array,
          q_pos: jax.Array, k_pos: jax.Array,
          window: "int | jax.Array" = 0, causal: bool = True,
          softcap: float = 0.0) -> jax.Array:
    """Dispatching flash attention (see module docstring)."""
    static_window = isinstance(window, int)
    if _pallas_enabled() and static_window and \
            q.shape[1] % 128 == 0 and k.shape[1] % 128 == 0:
        return _flash_pallas_diff(q, k, v, q_pos, k_pos, causal, window,
                                  softcap)
    return XF.flash_attention(q, k, v, q_pos, k_pos, window, causal,
                              softcap, 512, 512)


# ---------------------------------------------------------------------------
# Chunked-prefill attention (admission path)
# ---------------------------------------------------------------------------

# route a chunk's score matrix through the blocked flash path above this
# many C x S elements (below it the masked reference sdpa is cheaper)
PREFILL_CHUNK_FLASH_ELEMS = 1 << 22


def prefill_chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            q_pos: jax.Array, k_pos: jax.Array,
                            window: "int | jax.Array" = 0,
                            softcap: float = 0.0) -> jax.Array:
    """One prefill chunk's C queries against the slot's row cache.

    q: (B, C, H, D); k/v: (B, S, KV, D) — the row cache with positions
    [0, start + C) written (resident prefix + earlier chunks + this
    chunk).  Garbage beyond is causally dead: every unwritten slot's
    position exceeds every query's.  Causal + optional sliding window
    (``window`` may be a traced per-layer scalar).  Large score matrices
    route through the blocked flash path (Pallas when enabled); small
    shapes use the masked reference sdpa — numerically interchangeable.
    """
    if q.shape[1] * k.shape[1] >= PREFILL_CHUNK_FLASH_ELEMS:
        return flash(q, k, v, q_pos, k_pos, window, True, softcap)
    from repro.layers.attention import make_mask, sdpa
    mask = make_mask(q_pos, k_pos, "sliding", window)
    return sdpa(q, k, v, mask, softcap)


# ---------------------------------------------------------------------------
# Decode attention (O(1) cache-hit step)
# ---------------------------------------------------------------------------


def decode_attend_kv(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, softcap: float = 0.0
                     ) -> jax.Array:
    """q: (B, H, D); k/v: (B, S, KV, D); valid_len (B,)."""
    if _pallas_enabled() and q.shape[-1] % 8 == 0:
        return decode_attention_pallas(q, k, v, valid_len, softcap=softcap,
                                       interpret=_interpret())
    return REF.decode_reference(q, k, v, valid_len, softcap=softcap)


def int8_decode_fused(q: jax.Array, kq: jax.Array, vq: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array,
                      valid_len: jax.Array, softcap: float = 0.0,
                      window: int = 0) -> jax.Array:
    """Fused int8 decode: dequant happens inside the QK/AV loops (1 HBM
    byte per element).  Caller checks :func:`int8_fused_available`."""
    return decode_attention_pallas(
        q, kq, vq, valid_len, softcap=softcap, window=window,
        k_scale=k_scale, v_scale=v_scale, interpret=_interpret())


def int8_fused_available(window) -> bool:
    """The fused int8 kernel needs the Pallas path and a STATIC window
    (it is baked into the kernel); traced per-layer windows fall back to
    the dequantise-then-attend XLA path."""
    return _pallas_enabled() and isinstance(window, int)


# ---------------------------------------------------------------------------
# Paged decode attention (in-kernel page-table walk)
# ---------------------------------------------------------------------------


def paged_decode(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                 page_table: jax.Array, valid_len: jax.Array, *,
                 softcap: float = 0.0, window: "int | jax.Array" = 0,
                 k_scale=None, v_scale=None) -> jax.Array:
    """Layout-native paged decode attention: Pallas page-table-walk
    kernel on the Pallas path (compiled on TPU, interpret elsewhere),
    page-at-a-time XLA scan otherwise.  Neither materialises the dense
    (B, max_len, KV, D) logical view."""
    if _pallas_enabled():
        return paged_decode_attention_pallas(
            q, pool_k, pool_v, page_table, valid_len, softcap=softcap,
            window=window, k_scale=k_scale, v_scale=v_scale,
            interpret=_interpret())
    return paged_decode_attention_xla(
        q, pool_k, pool_v, page_table, valid_len, softcap=softcap,
        window=window, k_scale=k_scale, v_scale=v_scale)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, a, b, c, chunk, init_state=None):
    if _pallas_enabled():
        return ssd_scan_pallas(x, dt, a, b, c, chunk, init_state,
                               interpret=_interpret())
    from repro.layers.ssm import ssd_chunked
    return ssd_chunked(x, dt, a, b, c, chunk, init_state)

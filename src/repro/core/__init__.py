from repro.core import tconst  # noqa: F401

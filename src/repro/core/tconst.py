"""TConstFormer: the paper's contribution as a composable JAX module.

Architecture (paper §3, Fig 1b/2/3).  One TConst block has equivalent depth
``h + 2``; equivalent layer ``i`` owns ONE attention and ONE FFN parameter
set (parameter parity with an (h+2)-layer standard decoder, §6.2.1) which
is reused by every information-flow edge at that depth:

  layer 0      : context COMPRESS  (Q = history tail of length W_oh,
                 K/V = full history, causal)           [Fig 2c]
                 + generation causal self-attention (no cross yet — C_0 is
                 produced at this depth, so the gen window consumes it one
                 layer later; this yields exactly the paper's H+1 cross-
                 attention count, Appendix A.1)
  layers 1..h  : context self-attention over the W_oh slots (causal)
                 + generation causal self-attention
                 + generation cross-attention to C_{i-1}
  layer h+1    : context RESTORE (Q = full history, K/V = C_h) [Fig 2d]
                 (feeds the NEXT stacked block, paper Fig 3)
                 + generation causal self + cross to C_h

Causality: we keep every mask causal, following the paper's principle of
removing only the acausal connections.  RoPE positions are the true token
positions; a compressed slot inherits the position of the history-tail
token that produced it.

Complexity contract (validated in tests/benchmarks):
  cache hit  : (h+1)·D·W_oh + (h+2)·D·W_og²   — O(1) in N     (Eq. 5)
  cache miss : D[2·N·W_oh + …]                 — O(N)          (Eq. 4)
  KV cache   : 2B(h+1)W_oh·d + 2B(h+2)W_og·d  per block — O(1) (Eq. 7)

``mode="tlin"`` enables the prior-work TLinFormer topology: the severed
first-layer pathways from raw history to the generation window are
restored, which makes both the cache and the cache-hit cost O(N) again —
the paper's Fig 1a baseline.
"""
from __future__ import annotations

import functools
import hashlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.layers import attention as A
from repro.layers import embed as E
from repro.layers import rope as R
from repro.layers.common import (Params, init_rmsnorm, put_rows, rmsnorm,
                                 split_keys, take_rows, where_rows)
from repro.layers.mlp import init_swiglu, swiglu
from repro.layers.moe import init_moe, moe_ffn
from repro.models import layouts as LT

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, kf = split_keys(key, 2)
    ffn = init_moe(kf, cfg) if cfg.is_moe else \
        init_swiglu(kf, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return {
        "attn": A.init_attention(ka, cfg),
        "ffn": ffn,
        "ln1": init_rmsnorm(cfg.d_model, cfg.param_dtype),
        "ln2": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def _init_block(key: jax.Array, cfg: ModelConfig) -> Params:
    depth = cfg.tconst.block_depth
    keys = split_keys(key, depth)
    return {"layers": [_init_layer(k, cfg) for k in keys]}


def init_tconst_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kb = split_keys(key, 2)
    n_blocks = cfg.tconst_blocks
    block_keys = jax.random.split(kb, n_blocks)
    blocks = jax.vmap(lambda k: _init_block(k, cfg))(block_keys)
    return {
        "embed": E.init_embed(ke, cfg),
        "blocks": blocks,                       # leading dim = n_blocks
        "final_norm": init_rmsnorm(cfg.d_model, cfg.param_dtype),
    }


def _ffn_apply(layer: Params, x: jax.Array, cfg: ModelConfig
               ) -> Tuple[jax.Array, jax.Array]:
    if cfg.is_moe:
        y, aux = moe_ffn(layer["ffn"], x, cfg)
        return y, aux
    return swiglu(layer["ffn"], x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Context path (compress -> h self-attn -> restore)
# ---------------------------------------------------------------------------


def _rope(pos: jax.Array, cfg: ModelConfig):
    return R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)


FLASH_MIN_ELEMS = 4 * 1024 * 1024     # route big ctx attentions via flash


def _flash_ctx_attend(li: Params, xq_n: jax.Array, xkv_n: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array,
                      k_valid: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Blocked (flash) cross-attention for the context path's two O(N)
    hot spots — compress (Fig 2c) and restore (Fig 2d).  Naive sdpa
    materialises (B, KV, Lq, Lk) logits: 2.7+ GiB at 524k context.
    Positions may be per-batch (resync: hist_len differs per row)."""
    from repro.kernels.xla_flash import INVALID_POS, flash_attention
    dtype = xq_n.dtype
    q, k, v = A.qkv_proj(li["attn"], xq_n, xkv_n, dtype)
    cq, sq = _rope(jnp.maximum(q_pos, 0), cfg)
    ck, sk = _rope(jnp.maximum(k_pos, 0), cfg)
    q = R.apply_rope(q, cq, sq)
    k = R.apply_rope(k, ck, sk)
    kp = jnp.where(k_valid, k_pos, INVALID_POS)
    o = flash_attention(q, k, v, q_pos, kp, 0, True, cfg.logit_softcap,
                        256, 1024)
    return A.out_proj(li["attn"], o, dtype)


def context_path(block: Params, hist: jax.Array, hist_pos: jax.Array,
                 hist_valid: jax.Array, tail_pos: jax.Array,
                 tail_valid: jax.Array, cfg: ModelConfig,
                 ) -> Tuple[List[jax.Array], jax.Array, jax.Array]:
    """Run the context path of one block.

    hist: (B, N, D) full history buffer; hist_valid: (B, N) bool;
    tail_pos/tail_valid: (B, W_oh).  Returns (c_states [C_0..C_h] each
    (B, W_oh, D), restored history (B, N, D), aux loss).
    """
    eps = cfg.norm_eps
    h = cfg.tconst.h
    layers = block["layers"]
    B, N, D = hist.shape
    aux = jnp.zeros((), jnp.float32)

    cos_h, sin_h = _rope(hist_pos, cfg)
    cos_t, sin_t = _rope(jnp.maximum(tail_pos, 0), cfg)

    # gather tail tokens from the history buffer
    idx = jnp.clip(tail_pos, 0, N - 1)
    tail_x = jnp.take_along_axis(hist, idx[..., None], axis=1)   # (B,W_oh,D)

    # ---- layer 0: COMPRESS (Fig 2c) --------------------------------------
    l0 = layers[0]
    big = tail_pos.shape[-1] * N >= FLASH_MIN_ELEMS
    if big:
        c = tail_x + _flash_ctx_attend(
            l0, rmsnorm(l0["ln1"], tail_x, eps),
            rmsnorm(l0["ln1"], hist, eps), tail_pos, hist_pos,
            hist_valid, cfg)
    else:
        mask = A.make_mask(tail_pos, hist_pos, "causal")
        mask = jnp.logical_and(mask, hist_valid[:, None, :])
        c = tail_x + A.attention_block(
            l0["attn"], rmsnorm(l0["ln1"], tail_x, eps),
            rmsnorm(l0["ln1"], hist, eps), mask,
            cos_t, sin_t, cos_h, sin_h, cfg.logit_softcap)
    f, a0 = _ffn_apply(l0, rmsnorm(l0["ln2"], c, eps), cfg)
    c = c + f
    aux = aux + a0
    c_states = [c]

    # ---- layers 1..h: context self-attention ------------------------------
    tmask = A.make_mask(tail_pos, tail_pos, "causal")
    tmask = jnp.logical_and(tmask, tail_valid[:, None, :])
    for i in range(1, h + 1):
        li = layers[i]
        cn = rmsnorm(li["ln1"], c, eps)
        c = c + A.attention_block(li["attn"], cn, cn, tmask,
                                  cos_t, sin_t, cos_t, sin_t,
                                  cfg.logit_softcap)
        f, ai = _ffn_apply(li, rmsnorm(li["ln2"], c, eps), cfg)
        c = c + f
        aux = aux + ai
        c_states.append(c)

    # ---- layer h+1: RESTORE (Fig 2d) — feeds the next stacked block -------
    lf = layers[h + 1]
    if big:
        r = hist + _flash_ctx_attend(
            lf, rmsnorm(lf["ln1"], hist, eps),
            rmsnorm(lf["ln1"], c, eps), hist_pos, tail_pos,
            tail_valid, cfg)
    else:
        rmask = A.make_mask(hist_pos, tail_pos, "causal")
        rmask = jnp.logical_and(rmask, tail_valid[:, None, :])
        r = hist + A.attention_block(
            lf["attn"], rmsnorm(lf["ln1"], hist, eps),
            rmsnorm(lf["ln1"], c, eps), rmask,
            cos_h, sin_h, cos_t, sin_t, cfg.logit_softcap)
    f, af = _ffn_apply(lf, rmsnorm(lf["ln2"], r, eps), cfg)
    restored = r + f
    aux = aux + af
    return c_states, restored, aux


# ---------------------------------------------------------------------------
# Generation path (teacher-forced window pass — training / prefill)
# ---------------------------------------------------------------------------


def gen_path(block: Params, hg: jax.Array, gen_pos: jax.Array,
             c_states: List[jax.Array], tail_pos: jax.Array,
             tail_valid: jax.Array, cfg: ModelConfig,
             hist: Optional[jax.Array] = None,
             hist_pos: Optional[jax.Array] = None,
             hist_valid: Optional[jax.Array] = None,
             ) -> Tuple[jax.Array, jax.Array]:
    """Generation-window pass of one block.

    hg: (B, G, D) window activations; c_states from :func:`context_path`.
    When ``hist`` is given (mode="tlin") layer 0 additionally cross-attends
    to the raw history — the TLinFormer pathway the paper severs.
    Returns (hg_out, aux).
    """
    eps = cfg.norm_eps
    h = cfg.tconst.h
    layers = block["layers"]
    aux = jnp.zeros((), jnp.float32)

    cos_g, sin_g = _rope(gen_pos, cfg)
    cos_t, sin_t = _rope(jnp.maximum(tail_pos, 0), cfg)
    gmask = A.make_mask(gen_pos, gen_pos, "causal")

    for i in range(h + 2):
        li = layers[i]
        xn = rmsnorm(li["ln1"], hg, eps)
        out = A.attention_block(li["attn"], xn, xn, gmask,
                                cos_g, sin_g, cos_g, sin_g,
                                cfg.logit_softcap)
        if i >= 1:
            cs = c_states[i - 1]
            cn = rmsnorm(li["ln1"], cs, eps)
            cmask = A.make_mask(gen_pos, tail_pos, "causal")
            cmask = jnp.logical_and(cmask, tail_valid[:, None, :])
            out = out + A.attention_block(
                li["attn"], xn, cn, cmask,
                cos_g, sin_g, cos_t, sin_t, cfg.logit_softcap)
        elif hist is not None:
            # TLinFormer: first-layer direct pathway to raw history
            cos_h, sin_h = _rope(hist_pos, cfg)
            hmask = A.make_mask(gen_pos, hist_pos, "causal")
            hmask = jnp.logical_and(hmask, hist_valid[:, None, :])
            out = out + A.attention_block(
                li["attn"], xn, rmsnorm(li["ln1"], hist, eps), hmask,
                cos_g, sin_g, cos_h, sin_h, cfg.logit_softcap)
        hg = hg + out
        f, ai = _ffn_apply(li, rmsnorm(li["ln2"], hg, eps), cfg)
        hg = hg + f
        aux = aux + ai
    return hg, aux


# ---------------------------------------------------------------------------
# Training forward: sliding-window chunked processing (paper §5.1)
# ---------------------------------------------------------------------------


def tconst_forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   mode: str = "tconst") -> Tuple[jax.Array, jax.Array]:
    """Full teacher-forced forward.  tokens: (B, N) with N % W_og == 0.

    Processes the sequence in ``N // W_og`` chunks; chunk j sees chunks
    0..j-1 as (compressed) history.  Returns (logits (B, N, V), aux).
    """
    tc = cfg.tconst
    B, N = tokens.shape
    assert N % tc.w_og == 0, (N, tc.w_og)
    nc = N // tc.w_og
    dtype = jnp.dtype(cfg.dtype)

    from repro.sharding.rules import shard_act
    X = shard_act(E.embed_tokens(params["embed"], tokens, dtype))  # (B,N,D)
    pos = jnp.broadcast_to(jnp.arange(N)[None], (B, N))
    use_tlin = mode == "tlin"

    def chunk_body(_, j):
        hist_valid = pos < j * tc.w_og                           # (B, N)
        tail_pos = j * tc.w_og - tc.w_oh + jnp.arange(tc.w_oh)
        tail_pos = jnp.broadcast_to(tail_pos[None], (B, tc.w_oh))
        tail_valid = tail_pos >= 0
        gen_pos = j * tc.w_og + jnp.arange(tc.w_og)
        gen_pos = jnp.broadcast_to(gen_pos[None], (B, tc.w_og))
        hg0 = jax.lax.dynamic_slice_in_dim(X, j * tc.w_og, tc.w_og, axis=1)

        def block_body(carry, block):
            hist, hg, aux = carry
            c_states, restored, a_ctx = context_path(
                block, hist, pos, hist_valid, tail_pos, tail_valid, cfg)
            hg, a_gen = gen_path(
                block, hg, gen_pos, c_states, tail_pos, tail_valid, cfg,
                hist=hist if use_tlin else None,
                hist_pos=pos if use_tlin else None,
                hist_valid=hist_valid if use_tlin else None)
            return (restored, hg, aux + a_ctx + a_gen), None

        (_, hg, aux), _ = jax.lax.scan(
            block_body, (X, hg0, jnp.zeros((), jnp.float32)),
            params["blocks"])
        hg = rmsnorm(params["final_norm"], hg, cfg.norm_eps)
        logits = E.lm_head(params["embed"], hg, cfg.logit_softcap)
        return None, (logits, aux)

    _, (logits, aux) = jax.lax.scan(chunk_body, None, jnp.arange(nc))
    # logits: (nc, B, W_og, V) -> (B, N, V)
    logits = jnp.moveaxis(logits, 0, 1).reshape(B, N, -1)
    return logits, jnp.sum(aux)


# ---------------------------------------------------------------------------
# Inference: O(1) cache, cache-hit decode step, periodic resync
# ---------------------------------------------------------------------------


def init_tconst_cache(cfg: ModelConfig, batch: int, max_len: int,
                      mode: str = "tconst") -> Dict[str, Any]:
    """The paper's Eq. (7) constant-size cache (+ the raw token id buffer,
    int32, which is not KV-cache and is the only O(N) residue)."""
    tc = cfg.tconst
    nb = cfg.tconst_blocks
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {
        "tokens": jnp.zeros((batch, max_len), jnp.int32),
        "hist_len": jnp.zeros((batch,), jnp.int32),
        "gen_len": jnp.zeros((batch,), jnp.int32),
        "done": jnp.zeros((batch,), bool),
        "ctx_k": jnp.zeros((nb, tc.h + 1, batch, tc.w_oh, kv, hd), dt),
        "ctx_v": jnp.zeros((nb, tc.h + 1, batch, tc.w_oh, kv, hd), dt),
        "ctx_valid": jnp.zeros((batch, tc.w_oh), bool),
        "gen_k": jnp.zeros((nb, tc.h + 2, batch, tc.w_og, kv, hd), dt),
        "gen_v": jnp.zeros((nb, tc.h + 2, batch, tc.w_og, kv, hd), dt),
    }
    if mode == "tlin":
        # TLinFormer restores the O(N) first-layer history KV per block.
        cache["hist_k"] = jnp.zeros((nb, batch, max_len, kv, hd), dt)
        cache["hist_v"] = jnp.zeros((nb, batch, max_len, kv, hd), dt)
    return cache


def kv_cache_bytes(cache: Dict[str, Any]) -> int:
    """KV-cache footprint (the quantity in paper Fig 8g)."""
    keys = [k for k in cache if k.endswith("_k") or k.endswith("_v")]
    return sum(cache[k].size * cache[k].dtype.itemsize for k in keys)


# True KV-cache entries vs bookkeeping (token ids, lengths, phase flags) —
# the explicit partition behind :class:`repro.models.api.DecodeState`.
KV_KEYS = ("ctx_k", "ctx_v", "gen_k", "gen_v", "hist_k", "hist_v")

# Batch ("slot") axis of every cache entry, so the serving layer can
# scatter a prefilled row into a slot / select rows at a resync boundary.
CACHE_BATCH_AXES = {
    "tokens": 0, "hist_len": 0, "gen_len": 0, "done": 0, "ctx_valid": 0,
    "ctx_k": 2, "ctx_v": 2, "gen_k": 2, "gen_v": 2,
    "hist_k": 1, "hist_v": 1,
}

# Cache-layout metadata (repro.models.layouts): which KV fields have an
# O(N) length axis that a PagedLayout can split into pages (only the
# TLinFormer history KV — the tconst ctx/gen buffers are already O(1)),
# and which are float KV that a QuantizedLayout may store as int8.
LENGTH_AXES = {"hist_k": 2, "hist_v": 2}
QUANT_FIELDS = KV_KEYS


def needs_resync(cache: Dict[str, Any], cfg: ModelConfig) -> jax.Array:
    """Per-row (B,) bool: the generation window is full, the next decode
    step must be preceded by a global synchronisation."""
    return cache["gen_len"] >= cfg.tconst.w_og


def resync_rows(params: Params, cache: Dict[str, Any], cfg: ModelConfig,
                rows: jax.Array, mode: str = "tconst") -> Dict[str, Any]:
    """Row-selective resync: apply :func:`resync` only to the batch rows
    where ``rows`` is True, leaving the others bit-identical.

    This is what makes the periodic synchronisation correct under
    continuous batching: slots admitted at different times sit at
    different W_og phases, so a boundary crossing in one slot must not
    fold another slot's half-full generation window into history.
    """
    new = resync(params, cache, cfg, mode)
    return {k: where_rows(rows, new[k], cache[k], CACHE_BATCH_AXES[k])
            for k in cache}


def maybe_resync(params: Params, cache: Dict[str, Any], cfg: ModelConfig,
                 mode: str = "tconst") -> Dict[str, Any]:
    """Device-side resync decision (no host round-trip): a ``lax.cond`` on
    the per-row phase counters runs the linear-time synchronisation only
    when some row's generation window is full.  Fusing this into the
    jitted decode step lets a whole decode chunk run as one ``lax.scan``
    with zero per-token host syncs.

    PR-1 reference path: the cond computes the FULL-BATCH resync and
    row-selects, so non-boundary rows are computed then discarded.  The
    serving protocol now uses :func:`resync_rows_compacted` instead;
    this stays as the equivalence oracle for the parity tests.
    """
    rows = needs_resync(cache, cfg)
    return jax.lax.cond(
        jnp.any(rows),
        lambda c: resync_rows(params, c, cfg, rows, mode),
        lambda c: c,
        cache)


def gather_row(cache: Dict[str, Any], i: jax.Array) -> Dict[str, Any]:
    """Extract batch row ``i`` of every cache entry (batch size 1)."""
    return {k: jax.lax.dynamic_slice_in_dim(v, i, 1, CACHE_BATCH_AXES[k])
            for k, v in cache.items()}


def scatter_row(cache: Dict[str, Any], i: jax.Array,
                row: Dict[str, Any]) -> Dict[str, Any]:
    """Write a batch-1 row back into batch row ``i``."""
    return {k: jax.lax.dynamic_update_slice_in_dim(
        cache[k], row[k].astype(cache[k].dtype), i, CACHE_BATCH_AXES[k])
        for k in cache}


def pending_resync_rows(cache: Dict[str, Any], cfg: ModelConfig
                        ) -> jax.Array:
    """(B,) bool: rows that must sync before the next step — the window
    is full AND the slot is not EOS-finished (done rows are frozen by
    the chunk, so syncing them would be wasted O(N) work every step).
    Reads ONLY bookkeeping counters — no KV access, no unpack."""
    return jnp.logical_and(needs_resync(cache, cfg),
                           jnp.logical_not(cache["done"]))


# -- batched compacted resync (one dispatch for all pending rows) -----------

# resync() rebuilds the ctx/hist KV entirely from the raw token buffer, so
# a row-wise resync only ever needs to GATHER these bookkeeping fields —
# never the KV cache itself.
RESYNC_INPUT_KEYS = ("tokens", "hist_len", "gen_len")


def admission_digest(tokens, mode: str, w_og: int) -> bytes:
    """Content key of a TConst POST-ADMISSION slot state.

    ``resync`` (and therefore the bucketed admission prefill) rebuilds
    the ctx/hist KV purely from ``RESYNC_INPUT_KEYS`` — the raw token
    ids plus the deterministic hist/gen split, itself a function of the
    prompt length and ``w_og`` — so for fixed params/config the admitted
    slot (KV *and* bookkeeping) is a pure function of the prompt ids.
    That purity is what makes the ctx/hist KV content-addressable: two
    admissions of the same prompt may share one stored snapshot, and a
    tier-store hit replaces the O(N) resync with an O(1) restore.  The
    digest is salted with ``mode`` (tconst vs tlin caches differ) and
    ``w_og`` (it fixes the split); the caller layers scheduler-level
    salt (layout, max_len) on top."""
    h = hashlib.sha1(f"tconst-admit\x00{mode}\x00{w_og}\x00".encode())
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


def resync_buckets(batch: int) -> Tuple[int, ...]:
    """Static gather sizes for the compacted resync: 0, powers of two,
    and the full batch.  The pending count is rounded UP to the nearest
    bucket, so at most 2x the pending rows are computed while the number
    of compiled resync variants stays O(log batch)."""
    sizes = {0, batch}
    k = 1
    while k < batch:
        sizes.add(k)
        k *= 2
    return tuple(sorted(sizes))


def compacted_rows_switch(rows: jax.Array, operand: Any, branch_factory):
    """Shared scaffold of the batched compacted resync: sort pending
    rows first, round their count up to a static bucket, and dispatch
    ONE ``lax.switch`` branch.  ``branch_factory(k)`` returns
    ``fn(operand, idx (k,), sel (k,) bool) -> operand`` — ``idx`` are
    the rows to gather (pending first, then padding) and ``sel`` masks
    the padding rows out of the scatter.  Used by the dense-dict oracle
    (:func:`resync_rows_compacted`) and the layout-aware
    ``TConstDecode.sync_rows`` so the bucketing policy lives in exactly
    one place.  Zero pending rows selects the identity branch."""
    buckets = resync_buckets(rows.shape[0])
    order = jnp.argsort(jnp.logical_not(rows))       # pending rows first
    count = jnp.sum(rows)

    def wrap(kb: int):
        if kb == 0:
            return lambda op: op
        branch = branch_factory(kb)
        return lambda op: branch(op, order[:kb], jnp.arange(kb) < count)

    index = jnp.searchsorted(jnp.asarray(buckets), count)
    return jax.lax.switch(index, [wrap(k) for k in buckets], operand)


def resync_rows_compacted(params: Params, cache: Dict[str, Any],
                          cfg: ModelConfig, rows: jax.Array,
                          mode: str = "tconst") -> Dict[str, Any]:
    """Compacted row-wise resync, BATCHED: gather all pending rows in ONE
    dispatch, run ONE O(N) synchronisation at (bucketed) batch size k,
    and scatter the results back — non-pending rows are never computed
    and come through bit-identical.

    This replaces the PR-2 ``lax.while_loop`` that serialized one
    batch-1 resync per pending row (the ROADMAP follow-up: a PARTIALLY
    synchronized batch paid latency linear in its pending count).  The
    pending count is dynamic, so the gather size is rounded up to a
    static bucket (0, 1, 2, 4, ..., B — ``lax.switch`` on the count);
    padding rows are non-pending rows whose results are masked out of
    the scatter, wasting at most 2x the pending compute while keeping
    the dispatch count at exactly one.  Because ``resync`` rebuilds the
    ctx/hist KV from the raw token ids, only the ``RESYNC_INPUT_KEYS``
    bookkeeping rows are gathered — the KV cache is written, never read.

    Zero pending rows selects the identity branch, so this IS the fused
    on-device decision — no outer ``lax.cond`` needed.
    """
    def factory(kb: int):
        def branch(cache, idx, sel):
            row_in = {f: take_rows(cache[f], idx, CACHE_BATCH_AXES[f])
                      for f in RESYNC_INPUT_KEYS}
            new = resync(params, row_in, cfg, mode)
            out = dict(cache)
            for f, v in new.items():
                ax = CACHE_BATCH_AXES[f]
                old = take_rows(cache[f], idx, ax)
                vals = where_rows(sel, v.astype(cache[f].dtype), old, ax)
                out[f] = put_rows(cache[f], idx, vals, ax)
            return out
        return branch

    return compacted_rows_switch(rows, cache, factory)


def resync(params: Params, cache: Dict[str, Any], cfg: ModelConfig,
           mode: str = "tconst") -> Dict[str, Any]:
    """Cache-miss path: global information synchronisation (paper's k-th
    step).  Folds the generation window into history and recomputes the
    compressed context KV from the full token buffer.  Cost O(N)."""
    tc = cfg.tconst
    eps = cfg.norm_eps
    B, max_len = cache["tokens"].shape
    dtype = jnp.dtype(cfg.dtype)

    from repro.sharding.rules import shard_act
    hist_len = cache["hist_len"] + cache["gen_len"]              # (B,)
    X = shard_act(E.embed_tokens(params["embed"], cache["tokens"], dtype))
    pos = jnp.broadcast_to(jnp.arange(max_len)[None], (B, max_len))
    hist_valid = pos < hist_len[:, None]
    tail_pos = hist_len[:, None] - tc.w_oh + jnp.arange(tc.w_oh)[None]
    tail_valid = tail_pos >= 0
    cos_t, sin_t = _rope(jnp.maximum(tail_pos, 0), cfg)
    cos_h, sin_h = _rope(pos, cfg)

    def block_body(hist, block):
        c_states, restored, _ = context_path(
            block, hist, pos, hist_valid, tail_pos, tail_valid, cfg)
        cks, cvs = [], []
        for i in range(1, tc.h + 2):
            li = block["layers"][i]
            cn = rmsnorm(li["ln1"], c_states[i - 1], eps)
            ck, cv = A.project_kv(li["attn"], cn, cos_t, sin_t)
            cks.append(ck)
            cvs.append(cv)
        extras = ()
        if mode == "tlin":
            l0 = block["layers"][0]
            hk, hv = A.project_kv(
                l0["attn"], rmsnorm(l0["ln1"], hist, eps), cos_h, sin_h)
            extras = (hk, hv)
        return restored, (jnp.stack(cks), jnp.stack(cvs)) + extras

    _, outs = jax.lax.scan(block_body, X, params["blocks"])
    cache = dict(cache)
    cache["ctx_k"], cache["ctx_v"] = outs[0], outs[1]
    if mode == "tlin":
        cache["hist_k"], cache["hist_v"] = outs[2], outs[3]
    cache["ctx_valid"] = tail_valid
    cache["hist_len"] = hist_len
    cache["gen_len"] = jnp.zeros_like(cache["gen_len"])
    return cache


def decode_step_views(params: Params, cache: Dict[str, Any],
                      token: jax.Array, cfg: ModelConfig,
                      mode: str = "tconst"
                      ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Layout-native cache-hit step (paper Eq. 5): strictly O(1) compute
    and memory reads for mode="tconst".  ``cache`` maps bookkeeping names
    to plain arrays and KV names to :mod:`repro.models.layouts`
    FieldViews — the attention consumes the PHYSICAL representation
    (paged pools are walked page-by-page, int8 dequant rides the QK/AV
    loops) and the new token's K/V is appended *through* the views, so
    non-dense layouts never round-trip a dense logical cache.

    token: (B,) int32.  Returns (logits (B, V), updated cache dict with
    the same view/array structure).
    """
    tc = cfg.tconst
    eps = cfg.norm_eps
    B = token.shape[0]
    dtype = jnp.dtype(cfg.dtype)

    pos = cache["hist_len"] + cache["gen_len"]                   # (B,)
    x = E.embed_tokens(params["embed"], token[:, None], dtype)   # (B,1,D)
    cos_q, sin_q = _rope(pos[:, None], cfg)
    nb = cfg.tconst_blocks
    ctx_k, ctx_v = cache["ctx_k"], cache["ctx_v"]
    use_tlin = mode == "tlin"

    def block_body(ib, carry):
        x, gk, gv = carry
        block = jax.tree_util.tree_map(lambda a: a[ib], params["blocks"])
        ctx_kb, ctx_vb = ctx_k.layer(ib), ctx_v.layer(ib)
        gkb, gvb = gk.layer(ib), gv.layer(ib)
        for i in range(tc.h + 2):
            li = block["layers"][i]
            xn = rmsnorm(li["ln1"], x, eps)
            out, gki, gvi = A.decode_attend_view(
                li["attn"], xn, gkb.layer(i), gvb.layer(i),
                cache["gen_len"], cos_q, sin_q, cfg.logit_softcap)
            gkb = gkb.set_layer(i, gki)
            gvb = gvb.set_layer(i, gvi)
            if i >= 1:
                out = out + A.cross_attend_view(
                    li["attn"], xn, ctx_kb.layer(i - 1),
                    ctx_vb.layer(i - 1), cache["ctx_valid"],
                    cos_q, sin_q, cfg.logit_softcap)
            elif use_tlin:
                # TLinFormer's O(N) history KV: the ONE paged field of
                # this family — attended in its physical layout
                out = out + A.cross_attend_view(
                    li["attn"], xn, cache["hist_k"].layer(ib),
                    cache["hist_v"].layer(ib), None, cos_q, sin_q,
                    cfg.logit_softcap, valid_len=cache["hist_len"])
            x = x + out
            f, _ = _ffn_apply(li, rmsnorm(li["ln2"], x, eps), cfg)
            x = x + f
        return x, gk.set_layer(ib, gkb), gv.set_layer(ib, gvb)

    x, gk, gv = jax.lax.fori_loop(
        0, nb, lambda i, c: block_body(i, c),
        (x, cache["gen_k"], cache["gen_v"]))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)[:, 0]

    cache = dict(cache)
    cache["gen_k"], cache["gen_v"] = gk, gv
    # record the token id into the O(N) id buffer (int32, not KV cache)
    cache["tokens"] = cache["tokens"].at[jnp.arange(B), pos].set(token)
    cache["gen_len"] = cache["gen_len"] + 1
    return logits, cache


def _dense_views(cache: Dict[str, Any]) -> Dict[str, Any]:
    return {k: LT.DenseView(v, CACHE_BATCH_AXES[k]) if k in KV_KEYS else v
            for k, v in cache.items()}


def _undense_views(cache: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v.dense() if isinstance(v, LT.FieldView) else v
            for k, v in cache.items()}


def decode_step(params: Params, cache: Dict[str, Any], token: jax.Array,
                cfg: ModelConfig, mode: str = "tconst"
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Dense-dict cache-hit step: the legacy entry point (launchers,
    benchmarks) and the PARITY ORACLE the layout-native kernels are
    tested against.  Wraps the dense arrays in DenseViews — the
    dense-view dispatch is bit-identical to the historic dense path.

    The caller (or :func:`repro.serving.engine`) must invoke :func:`resync`
    once ``gen_len`` reaches ``W_og`` — the paper's periodic linear-time
    synchronisation.
    """
    logits, out = decode_step_views(params, _dense_views(cache), token,
                                    cfg, mode)
    return logits, _undense_views(out)


def verify_chunk_views(params: Params, cache: Dict[str, Any],
                       feed: jax.Array, cfg: ModelConfig,
                       mode: str = "tconst"
                       ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Speculative VERIFY: score C fed tokens per slot against the
    resident caches in ONE fixed-shape dispatch — the chunked analogue
    of :func:`decode_step_views`, with the C-step python loop replaced
    by :func:`repro.kernels.ops.prefill_chunk_attention` over the gen
    window and C-query cross-attention over the frozen context KV.

    feed: (B, C) int32 — position c is the token the sequential decode
    WOULD feed at generation offset ``gen_len + c`` (the previous
    sample, then the draft).  All C keys/values are written through the
    views at gen slots ``gen_len + c`` (true-position RoPE), exactly
    where the sequential steps would put them; writes past ``W_og``
    fall off the scatter harmlessly and the caller's acceptance budget
    (:meth:`TConstDecode.verify_budget`) never accepts past the window.

    COUNTERS ARE NOT ADVANCED: acceptance of an m-token prefix is a
    later ``gen_len += m`` (``advance_lengths``); rejected suffix
    writes become stale garbage beyond ``gen_len``, masked by the
    slot-causal attention here and overwritten before ever being
    attended by the next round's writes at the same slots.

    Returns (logits (B, C, V) — position c scores the token AFTER
    ``feed[:, c]`` — and the updated cache, same view structure).
    """
    from repro.kernels import ops
    tc = cfg.tconst
    eps = cfg.norm_eps
    B, C = feed.shape
    dtype = jnp.dtype(cfg.dtype)

    pos = cache["hist_len"] + cache["gen_len"]                   # (B,)
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None]   # (B, C)
    gpos = cache["gen_len"][:, None] + \
        jnp.arange(C, dtype=jnp.int32)[None]                     # (B, C)
    x = E.embed_tokens(params["embed"], feed, dtype)             # (B, C, D)
    cos_q, sin_q = _rope(qpos, cfg)
    nb = cfg.tconst_blocks
    ctx_k, ctx_v = cache["ctx_k"], cache["ctx_v"]
    use_tlin = mode == "tlin"
    if use_tlin:
        max_len = cache["tokens"].shape[1]
        hist_valid = jnp.arange(max_len)[None] < \
            cache["hist_len"][:, None]                           # (B, N)

    def block_body(ib, carry):
        x, gk, gv = carry
        block = jax.tree_util.tree_map(lambda a: a[ib], params["blocks"])
        ctx_kb, ctx_vb = ctx_k.layer(ib), ctx_v.layer(ib)
        gkb, gvb = gk.layer(ib), gv.layer(ib)
        for i in range(tc.h + 2):
            li = block["layers"][i]
            xn = rmsnorm(li["ln1"], x, eps)
            q, k_new, v_new = A.qkv_proj(li["attn"], xn, xn, dtype)
            q = R.apply_rope(q, cos_q, sin_q)
            k_new = R.apply_rope(k_new, cos_q, sin_q)
            gki, gvi = gkb.layer(i), gvb.layer(i)
            for c in range(C):
                gki = gki.write_token(cache["gen_len"] + c, k_new[:, c])
                gvi = gvi.write_token(cache["gen_len"] + c, v_new[:, c])
            gkb = gkb.set_layer(i, gki)
            gvb = gvb.set_layer(i, gvi)
            w_og = gki.dense().shape[1]
            o = ops.prefill_chunk_attention(
                q, gki.dense().astype(dtype), gvi.dense().astype(dtype),
                gpos, jnp.arange(w_og, dtype=jnp.int32), 0,
                cfg.logit_softcap)
            out = A.out_proj(li["attn"], o, dtype)
            if i >= 1:
                out = out + A.verify_attend_view(
                    li["attn"], xn, ctx_kb.layer(i - 1),
                    ctx_vb.layer(i - 1), cache["ctx_valid"],
                    cos_q, sin_q, cfg.logit_softcap)
            elif use_tlin:
                out = out + A.verify_attend_view(
                    li["attn"], xn, cache["hist_k"].layer(ib),
                    cache["hist_v"].layer(ib), hist_valid,
                    cos_q, sin_q, cfg.logit_softcap)
            x = x + out
            f, _ = _ffn_apply(li, rmsnorm(li["ln2"], x, eps), cfg)
            x = x + f
        return x, gk.set_layer(ib, gkb), gv.set_layer(ib, gvb)

    x, gk, gv = jax.lax.fori_loop(
        0, nb, lambda i, c: block_body(i, c),
        (x, cache["gen_k"], cache["gen_v"]))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)   # (B, C, V)

    cache = dict(cache)
    cache["gen_k"], cache["gen_v"] = gk, gv
    cache["tokens"] = cache["tokens"].at[
        jnp.arange(B)[:, None], qpos].set(feed)
    return logits, cache


def _prefill_window_pass(params: Params, cache: Dict[str, Any],
                         win: jax.Array, gen_pos: jax.Array,
                         cfg: ModelConfig, mode: str
                         ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Teacher-forced generation-window pass shared by :func:`prefill`
    (window = the prompt's trailing 1..W_og tokens, static width W) and
    :func:`prefill_bucketed` (fixed W_og width, trailing padding masked
    by causality now and by ``gen_len`` afterwards).  Fills the leading
    W slots of the per-layer gen KV buffers (W < W_og: the rest stays
    zero).  Returns (hg (B, W, D), (gen_k, gen_v) stacked per block)."""
    tc = cfg.tconst
    eps = cfg.norm_eps
    B, W = win.shape
    dtype = jnp.dtype(cfg.dtype)
    cos_g, sin_g = _rope(gen_pos, cfg)
    hg = E.embed_tokens(params["embed"], win, dtype)
    gmask = A.make_mask(gen_pos, gen_pos, "causal")

    def block_body(hg, xs):
        if mode == "tlin":
            block, ctx_k, ctx_v, hist_k, hist_v = xs
        else:
            block, ctx_k, ctx_v = xs
        new_gk, new_gv = [], []
        for i in range(tc.h + 2):
            li = block["layers"][i]
            xn = rmsnorm(li["ln1"], hg, eps)
            k, v = A.project_kv(li["attn"], xn, cos_g, sin_g)
            q = jnp.einsum("bld,dhk->blhk", xn, li["attn"]["wq"].astype(dtype))
            q = R.apply_rope(q, cos_g, sin_g)
            out = A.out_proj(li["attn"], A.sdpa(
                q, k, v, gmask, cfg.logit_softcap), dtype)
            # store window K/V into slots [0, W)
            if W < tc.w_og:
                gk = jnp.zeros((B, tc.w_og) + k.shape[2:], dtype)
                gv = jnp.zeros((B, tc.w_og) + v.shape[2:], dtype)
                k = jax.lax.dynamic_update_slice_in_dim(gk, k, 0, axis=1)
                v = jax.lax.dynamic_update_slice_in_dim(gv, v, 0, axis=1)
            new_gk.append(k)
            new_gv.append(v)
            if i >= 1:
                out = out + A.cross_attend_cached(
                    li["attn"], xn, ctx_k[i - 1], ctx_v[i - 1],
                    cache["ctx_valid"], cos_g, sin_g, cfg.logit_softcap)
            elif mode == "tlin":
                slots = jnp.arange(hist_k.shape[1])[None]
                hvalid = slots < cache["hist_len"][:, None]
                out = out + A.cross_attend_cached(
                    li["attn"], xn, hist_k, hist_v, hvalid,
                    cos_g, sin_g, cfg.logit_softcap)
            hg = hg + out
            f, _ = _ffn_apply(li, rmsnorm(li["ln2"], hg, eps), cfg)
            hg = hg + f
        return hg, (jnp.stack(new_gk), jnp.stack(new_gv))

    xs = (params["blocks"], cache["ctx_k"], cache["ctx_v"])
    if mode == "tlin":
        xs = xs + (cache["hist_k"], cache["hist_v"])
    return jax.lax.scan(block_body, hg, xs)


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int, mode: str = "tconst"
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a prompt: resync over the history part, teacher-forced pass
    over the trailing (≤ W_og) generation-window part, fill all caches.

    tokens: (B, N0), N0 static.  Returns (next-token logits (B, V), cache).
    """
    tc = cfg.tconst
    B, n0 = tokens.shape
    g0 = ((n0 - 1) % tc.w_og) + 1            # window part: 1..W_og tokens

    cache = init_tconst_cache(cfg, B, max_len, mode)
    cache["tokens"] = jax.lax.dynamic_update_slice_in_dim(
        cache["tokens"], tokens, 0, axis=1)
    cache["hist_len"] = jnp.full((B,), n0 - g0, jnp.int32)
    cache["gen_len"] = jnp.zeros((B,), jnp.int32)
    cache = resync(params, cache, cfg, mode)     # gen_len folded in (=0)

    win = tokens[:, n0 - g0:]
    gen_pos = (n0 - g0) + jnp.broadcast_to(jnp.arange(g0)[None], (B, g0))
    hg, (gk, gv) = _prefill_window_pass(params, cache, win, gen_pos, cfg,
                                        mode)
    hg = rmsnorm(params["final_norm"], hg, cfg.norm_eps)
    logits = E.lm_head(params["embed"], hg, cfg.logit_softcap)[:, -1]
    cache["gen_k"], cache["gen_v"] = gk, gv
    cache["gen_len"] = jnp.full((B,), g0, jnp.int32)
    return logits, cache


def prefill_bucketed(params: Params, tokens: jax.Array, n_valid: jax.Array,
                     cfg: ModelConfig, mode: str = "tconst"
                     ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Bucketed-shape prefill: ONE compile for every prompt length.

    :func:`prefill` compiles once per distinct prompt length (its token
    argument and teacher-forced window are ``n0``-shaped).  Here the
    prompt arrives already zero-padded into the full ``(B, max_len)``
    token buffer with a TRACED per-row length ``n_valid``, the resync is
    its usual fixed-``max_len`` dispatch, and the generation-window pass
    runs at a fixed ``W_og`` width with validity masking — so the entire
    admission is shape-independent.  Written positions beyond each row's
    window part (``slots >= g0``) hold garbage that ``gen_len`` masks
    out of every later attend, exactly like the unchunked cache.

    tokens: (B, max_len) int32, zeros beyond ``n_valid`` (the resync
    embeds the whole buffer either way, so padding must match the
    unchunked token buffer bit-for-bit).  n_valid: (B,) int32 >= 1.
    Returns (next-token logits (B, V), cache) — stream-identical to
    :func:`prefill` up to float association.
    """
    tc = cfg.tconst
    B, max_len = tokens.shape
    g0 = ((n_valid - 1) % tc.w_og) + 1       # (B,) window part: 1..W_og

    cache = init_tconst_cache(cfg, B, max_len, mode)
    cache["tokens"] = tokens
    cache["hist_len"] = n_valid - g0
    cache["gen_len"] = jnp.zeros((B,), jnp.int32)
    cache = resync(params, cache, cfg, mode)     # fixed-shape O(max_len)

    # teacher-forced generation-window pass at fixed W_og width: row b's
    # window tokens are tokens[hist_len : hist_len + g0]; trailing slots
    # [g0, W_og) are padding whose K/V is never attended (masked by
    # gen_len afterwards, by causality inside this pass).
    win_pos = cache["hist_len"][:, None] + jnp.arange(tc.w_og)[None]
    win = jnp.take_along_axis(tokens, jnp.clip(win_pos, 0, max_len - 1),
                              axis=1)
    hg, (gk, gv) = _prefill_window_pass(params, cache, win, win_pos, cfg,
                                        mode)
    hg = rmsnorm(params["final_norm"], hg, cfg.norm_eps)
    logits = E.lm_head(params["embed"], hg, cfg.logit_softcap)  # (B,W_og,V)
    logits = jnp.take_along_axis(
        logits, (g0 - 1)[:, None, None], axis=1)[:, 0]
    cache["gen_k"], cache["gen_v"] = gk, gv
    cache["gen_len"] = g0
    return logits, cache

"""Production mesh construction.

v5e target: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.
A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_host_mesh():
    """1x1 mesh on the single real CPU device (tests / examples)."""
    auto = (jax.sharding.AxisType.Auto,) * 2
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=auto)


def make_decode_mesh(data: int, model: int):
    """(data, model) decode mesh over the first data*model local devices.

    Uses the plain ``jax.sharding.Mesh`` constructor (no AxisType — that
    API is newer than the pinned jax), so it works on any backend,
    including a CPU forced to N devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {data}x{model} needs {n} devices but only "
            f"{len(devices)} are visible (on CPU, set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n})")
    grid = np.asarray(devices[:n]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/initialisation: the dry-run builds the
#   production 16x16 (and 2x16x16) mesh out of 512 host placeholder
#   devices.  Never set this in conftest/pyproject — tests see 1 device.

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) pair this lowers + compiles the
appropriate step function against the production mesh with
ShapeDtypeStruct stand-ins (no allocation), then records:

  * memory_analysis()   — per-device argument/temp bytes (proves it fits)
  * cost_analysis()     — per-device HLO FLOPs / bytes (roofline inputs)
  * collective bytes    — parsed from the partitioned HLO text

Shape kinds map to functions: train_* -> train_step (fwd+bwd+AdamW,
microbatched), prefill_* -> prefill, decode_* -> serve_step (ONE token
against a seq_len cache).  long_500k applies the DESIGN.md §4 policy:
SSM/hybrid run natively, native-SWA archs run their sliding variant, and
pure full-attention archs run attention_mode="tconst" — the paper's O(1)
mechanism is precisely what makes a 524k-token decode state lowerable.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import (INPUT_SHAPES, ModelConfig, ShapeConfig, get_config,
                          get_shape, list_archs)
from repro.launch.mesh import make_production_mesh
from repro.models.api import ModelAPI, build_model
from repro.sharding import rules
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

ASSIGNED_ARCHS = [
    "mixtral-8x22b", "llama3-405b", "mamba2-130m", "deepseek-moe-16b",
    "smollm-360m", "minicpm-2b", "hymba-1.5b", "whisper-small",
    "gemma3-4b", "qwen2-vl-2b",
]

# ---------------------------------------------------------------------------
# Per-(arch, shape) policy
# ---------------------------------------------------------------------------

BIG_D_MODEL = 4096           # bf16 params + bf16 opt state + fsdp above this


def plan_config(arch: str, shape: ShapeConfig) -> ModelConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k":
        if cfg.arch_type in ("ssm", "hybrid"):
            pass                                    # recurrent state: native
        elif cfg.sliding_window > 0:
            cfg = cfg.replace(attention_mode="sliding") \
                if cfg.local_global_ratio == 0 else cfg   # gemma3 keeps 5:1
        else:
            # pure full attention: the paper's technique is the enabler
            cfg = cfg.replace(attention_mode="tconst")
    if shape.kind == "train" and cfg.d_model >= BIG_D_MODEL:
        cfg = cfg.replace(param_dtype="bfloat16")
    return cfg


def plan_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                      dsize: int = 16) -> int:
    if shape.kind != "train":
        return 1
    if cfg.d_model >= BIG_D_MODEL:
        want = 16
    elif cfg.d_model >= 2048:
        want = 8
    else:
        want = 4   # even small models: bounded-activation microbatches
    # each microbatch must still shard over the full data extent
    # (multi-pod: dsize=32; mb < dsize replicates activations — measured
    # 2x peak regression on mixtral multi-pod before this clamp)
    return max(1, min(want, shape.global_batch // dsize))


def _opt_cfg(cfg: ModelConfig) -> AdamWConfig:
    big = cfg.d_model >= BIG_D_MODEL
    # §Perf H1 it5: factored second moment for the HBM-edge configs —
    # optimizer state shrinks from 2x params to ~1x params (+ epsilon).
    return AdamWConfig(state_dtype="bfloat16" if big else "float32",
                       factored=big)


# ---------------------------------------------------------------------------
# HLO collective audit
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"((?:\w+\[[^\]]*\](?:\{[^}]*\})?,?\s*)+|\([^)]*\))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
    re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
             "f8e5m2": 1, "s16": 2, "u16": 2}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-operand bytes of every collective op in the partitioned
    module, by op kind.  Per-device quantities (SPMD module is local)."""
    out: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            if dt not in _DT_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DT_BYTES[dt]
        out[op] = out.get(op, 0.0) + nbytes
    out["total"] = sum(v for k, v in out.items())
    return out


# ---------------------------------------------------------------------------
# Lowering builders
# ---------------------------------------------------------------------------


def build_lowered(arch: str, shape_name: str, mesh,
                  verbose: bool = True) -> Tuple[Any, Dict[str, Any]]:
    shape = get_shape(shape_name)
    cfg = plan_config(arch, shape)
    api = build_model(cfg)
    fsdp = cfg.d_model >= BIG_D_MODEL
    # NOTE: seq_parallel=True was tried for the HBM-edge train configs and
    # REFUTED as a blanket constraint: peak stayed ~52 GiB while collective
    # bytes exploded 7->72 GiB/device (naive constraint placement forces an
    # all-gather at every attention).  See EXPERIMENTS.md §Perf iteration 3.
    rules.set_activation_context(mesh, seq_parallel=False)

    param_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    param_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                      for l in jax.tree_util.tree_leaves(param_shapes))
    # §Perf H2: for small models at PREFILL, tensor-parallel weight
    # sharding only buys per-layer all-reduces of full activations (the
    # most collective-bound pair, mamba2 prefill_32k, spent ~50% of its
    # roofline there).  Below 2 GiB of weights, replicate and keep pure
    # data parallelism.  DECODE keeps TP: it is parameter-read bound, and
    # replication multiplies per-device HBM traffic by the mesh size
    # (measured 500x worse t_mem on smollm long_500k — §Perf H2 it2,
    # refuted there).
    replicate_params = (shape.kind == "prefill"
                        and param_bytes <= 2 * 2**30
                        and shape.global_batch % 16 == 0)
    if replicate_params:
        param_sh = jax.tree_util.tree_map(
            lambda _: rules.replicated(mesh), param_shapes)
    else:
        param_sh = rules.param_shardings(param_shapes, mesh, fsdp=fsdp)
    info: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "attention_mode": cfg.attention_mode,
        "param_count": int(sum(np.prod(l.shape) for l in
                               jax.tree_util.tree_leaves(param_shapes))),
        "fsdp": fsdp,
    }

    if shape.kind == "train":
        opt_cfg = _opt_cfg(cfg)
        dsize = rules._axis_size(mesh, rules.data_axes(mesh))
        n_micro = plan_microbatches(cfg, shape, dsize)
        info["n_micro"] = n_micro
        opt_shapes = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), param_shapes)
        opt_sh = rules.opt_shardings(param_sh, opt_shapes, mesh, fsdp=fsdp)
        batch_specs = api.input_specs(shape)
        batch_sh = rules.batch_shardings(batch_specs, mesh)
        big = cfg.d_model >= BIG_D_MODEL
        step = make_train_step(
            api, opt_cfg, n_micro=n_micro,
            accum_dtype="bfloat16" if big else "float32",
            grad_shardings=param_sh)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(param_shapes, opt_shapes, batch_specs)
        return lowered, info

    if shape.kind == "prefill":
        batch_specs = api.input_specs(shape)
        batch_sh = rules.batch_shardings(batch_specs, mesh)
        cache_shapes = api.cache_specs(shape.global_batch, shape.seq_len)
        cache_sh = rules.cache_shardings(cache_shapes, mesh,
                                         shape.global_batch)
        fn = lambda p, b: api.prefill(p, b, shape.seq_len)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(param_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(param_shapes, batch_specs)
        return lowered, info

    # decode: serve_step = ONE new token against a seq_len cache
    B = shape.global_batch
    cache_shapes = api.cache_specs(B, shape.seq_len)
    cache_sh = rules.cache_shardings(cache_shapes, mesh, B)
    token_spec = jax.ShapeDtypeStruct((B,), jnp.int32)
    dsize = rules._axis_size(mesh, rules.data_axes(mesh))
    tok_sh = rules.batch_shardings({"t": token_spec}, mesh)["t"]
    serve_step = lambda p, c, t: api.decode_step(p, c, t)
    with mesh:
        lowered = jax.jit(
            serve_step,
            in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(1,),
        ).lower(param_shapes, cache_shapes, token_spec)
    return lowered, info


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, info = build_lowered(arch, shape_name, mesh, verbose)
    info["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t0, 2)

    mem = compiled.memory_analysis()
    info["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_bytes_est": int(mem.argument_size_in_bytes +
                              mem.temp_size_in_bytes +
                              mem.output_size_in_bytes -
                              mem.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis()
    info["cost"] = {"flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
    info["collectives"] = collective_bytes(compiled.as_text())
    if verbose:
        mb = info["memory"]["peak_bytes_est"] / 2**30
        print(f"[dryrun] {arch:18s} {shape_name:12s} mesh={info['mesh']:9s} "
              f"mode={info['attention_mode']:7s} "
              f"peak/dev={mb:7.2f}GiB flops/dev={info['cost']['flops']:.3e} "
              f"coll/dev={info['collectives']['total']/2**20:9.1f}MiB "
              f"compile={info['compile_s']:.1f}s", flush=True)
    return info


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in INPUT_SHAPES:
                pairs.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    results = []
    failures = 0
    for arch, shape in pairs:
        try:
            results.append(run_one(arch, shape, multi_pod=args.multi_pod))
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"[dryrun] {arch} {shape} FAILED: {type(e).__name__}: {e}",
                  flush=True)
            results.append({"arch": arch, "shape": shape, "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    print(f"[dryrun] done: {len(pairs) - failures}/{len(pairs)} OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

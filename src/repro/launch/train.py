"""Training launcher.

Runs real training on whatever devices exist (the CPU container trains the
paper's reduced configs; on a TPU pod the same entry point scales via the
production mesh).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch tconst-41m \\
      --steps 200 --batch 8 --seq 256 --reduced --log-every 10
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.data.pipeline import DataConfig, batches
from repro.models.api import build_model
from repro.training.checkpoint import save_train_state
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.schedules import warmup_cosine, wsd
from repro.training.train_step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tconst-41m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-scale) variant")
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--data", default="synthetic", choices=["synthetic",
                                                            "text"])
    ap.add_argument("--text-path", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg, vocab_size=args.vocab)
    if cfg.attention_mode in ("tconst", "tlin"):
        assert args.seq % cfg.tconst.w_og == 0, \
            f"--seq must be a multiple of W_og={cfg.tconst.w_og}"
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in
                   jax.tree_util.tree_leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.2f}M "
          f"mode={cfg.attention_mode}")

    opt_cfg = AdamWConfig(lr=args.lr)
    opt = init_opt_state(params, opt_cfg)
    sched = (wsd(args.steps // 20, int(args.steps * 0.85),
                 args.steps // 10) if args.schedule == "wsd"
             else warmup_cosine(args.steps // 20, args.steps))
    step_fn = jax.jit(make_train_step(api, opt_cfg, sched,
                                      n_micro=args.n_micro),
                      donate_argnums=(0, 1))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed,
                    kind=args.data, text_path=args.text_path)
    t0 = time.time()
    for i, b in enumerate(batches(dc, steps=args.steps)):
        batch = {"tokens": jnp.asarray(b["tokens"][:, :args.seq])}
        if cfg.arch_type == "vlm":
            Tv = cfg.frontend_tokens
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, Tv, cfg.frontend_dim), jnp.dtype(cfg.dtype))
            batch["vision_mask"] = jnp.zeros(
                (args.batch, args.seq), bool).at[:, :Tv].set(True)
        if cfg.is_encdec:
            batch["audio_feats"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.frontend_dim),
                jnp.dtype(cfg.dtype))
        params, opt, m = step_fn(params, opt, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            toks = args.batch * args.seq * (i + 1)
            print(f"[train] step {i:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"tok/s={toks/(time.time()-t0):9.0f}", flush=True)
    if args.ckpt_dir:
        path = save_train_state(params, opt, args.steps, args.ckpt_dir)
        print(f"[train] checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launcher: uniform-batch generation (Engine) or the session-
based streaming path (SlotScheduler) with continuous batching.

Both paths take ``--layout dense|paged|int8`` — the physical cache
representation behind the DecodeState (see ``repro.models.layouts``).
``paged`` splits length-axis KV into fixed-size pages (``--page-size``)
in a shared pool; ``--pool-pages`` sizes the pool below
``slots * pages_per_slot`` so short sessions stop paying ``max_len``
bytes (sessions mode only — the scheduler is the page allocator).
``int8`` stores KV quantized with per-vector scales (~4x smaller,
tokens may differ within the documented tolerance).

``--prefix-sharing`` (sessions mode, paged layouts) turns on the
refcounted content-addressed page map: every session gets the SAME
system prompt plus a distinct tail, and sessions admitted while the
prefix is resident map its pages instead of re-writing them — the
shared prefix is stored once, writes copy-on-write (a page is writable
iff its refcount is 1).

``--prefill-chunk N`` (sessions mode) switches admission to the chunked
KV-conditioned prefill: prompts are processed in fixed-size N-token
chunks attending the KV already resident in the slot (adopted
prefix-shared pages included), so prefill compiles are bounded by the
chunk shape instead of one per prompt length, and with
``--prefix-sharing`` a shared-prefix admission forwards only its
unshared tail.  See docs/serving.md for the full admission lifecycle.

Uniform batch (benchmark-style, same-length prompts)::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --prompt-len 64 --gen 64 --batch 4

Streaming sessions (per-request prompt lengths, staggered admission,
chunked zero-host-sync decode; prints each session's stream and checks
it against single-session generation)::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --sessions 3 --gen 24 --slots 2 --layout paged --page-size 16 \\
      --pool-pages 12

Shared-system-prompt demo (prefix sharing / CoW)::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --sessions 4 --slots 4 --gen 16 --prompt-len 64 \\
      --layout paged --page-size 16 --prefix-sharing

Chunked tail-only admission on top (bucketed prefill compiles)::

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \\
      --sessions 4 --slots 4 --gen 16 --prompt-len 64 \\
      --layout paged --page-size 16 --prefix-sharing --prefill-chunk 16

Session tiering (oversubscribed: sessions >> slots, idle sessions spill
to a host-RAM tier store and resume token-identically; prints spill /
resume cycles and assigned-vs-spilled bytes)::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --sessions 6 --slots 2 --gen 16 --layout paged --page-size 16 \\
      --spill-capacity-mb 64

Speculative decoding (sessions mode): each scheduler tick drafts k
tokens per slot (``--drafter ngram`` self-drafts from the session's own
window; ``tconst`` runs a reduced small-W model), verifies them in ONE
fixed-shape ``verify_chunk`` dispatch, and commits the verify-exact
accepted prefix — streams stay token-identical to the non-speculative
run (checked against solo generation below) while repeat-heavy text
commits up to k+1 tokens per dispatch::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --sessions 3 --slots 2 --gen 24 --speculate 4 --drafter ngram

SLO-aware scheduling demo (``--workload`` replays a seeded traffic
trace — poisson or bursty arrivals, length mixes, SLO slice — through
the scheduler under a named policy and prints the telemetry summary;
compare ``--policy fifo`` vs ``--policy slo`` on the same trace)::

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --sessions 8 --slots 2 --chunk 4 --max-len 104 \\
      --workload bursty --policy slo --slo-ttft-chunks 6
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.launch.mesh import make_decode_mesh
from repro.models.api import build_decode, build_model
from repro.models.layouts import LayoutSpec
from repro.serving.engine import Engine
from repro.serving.metrics import ServingTelemetry
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session
from repro.serving.tier_store import TierStore
from repro.serving.workload import WorkloadSpec, generate_workload


def _layout_spec(args) -> LayoutSpec:
    return LayoutSpec(kind=args.layout, page_size=args.page_size,
                      pool_pages=args.pool_pages or None)


def _session_prompt_lens(args) -> list:
    """Prompt lengths the sessions demo will submit.  Prefix sharing
    uses one common system prompt + equal-length distinct tails (equal
    lengths keep greedy parity with the solo runs bitwise-exact);
    otherwise lengths vary per session to exercise staggered phases."""
    if args.prefix_sharing:
        return [args.prompt_len + 8] * args.sessions
    return [args.prompt_len + 5 * i for i in range(args.sessions)]


def validate_layout_args(ap, cfg, args, max_len: int) -> None:
    """Startup validation of the paged-layout knobs against the model
    config and launch geometry, so a mis-sized pool fails with a clear
    message instead of a shape crash (or a scheduler rejection) at
    first admission."""
    if args.prefix_sharing:
        if not args.sessions:
            ap.error("--prefix-sharing needs --sessions N — the session "
                     "scheduler owns the prefix map and the page "
                     "refcounts (uniform batch has no admission path)")
        if args.layout not in ("paged", "paged_int8"):
            ap.error(f"--prefix-sharing shares pool PAGES; --layout "
                     f"{args.layout} has none (use paged or paged_int8)")
    if args.prefill_chunk < 0:
        ap.error(f"--prefill-chunk {args.prefill_chunk} must be positive "
                 f"(0 disables chunked admission)")
    if args.prefill_chunk:
        if not args.sessions:
            ap.error("--prefill-chunk shapes ADMISSION dispatches; the "
                     "uniform batch has no admission path (its prefill "
                     "is one fixed-shape dispatch already) — add "
                     "--sessions N")
        if args.layout in ("paged", "paged_int8") and \
                args.prefill_chunk % args.page_size != 0:
            ap.error(f"--prefill-chunk {args.prefill_chunk} must be a "
                     f"multiple of --page-size {args.page_size} — "
                     f"chunk-granular page writes cover whole pages")
    if args.speculate < 0:
        ap.error(f"--speculate {args.speculate} must be >= 0 (tokens "
                 f"drafted per slot per tick; 0 disables speculation)")
    if args.speculate and not args.sessions:
        ap.error("--speculate rides the session scheduler's verify "
                 "dispatch (the uniform batch path is greedy-Engine "
                 "only — see Engine.generate_speculative) — add "
                 "--sessions N")
    if args.workload and not args.sessions:
        ap.error("--workload replays a traffic trace through the session "
                 "scheduler (arrivals, SLOs, policies are admission-side "
                 "concepts; the uniform batch has none) — add --sessions N")
    if args.slo_ttft_chunks < 1:
        ap.error(f"--slo-ttft-chunks {args.slo_ttft_chunks} must be >= 1 "
                 f"(the deadline is counted in scheduler chunks from "
                 f"submission)")
    if args.spill_capacity_mb < 0:
        ap.error(f"--spill-capacity-mb {args.spill_capacity_mb} must be "
                 f"positive (0 disables session tiering)")
    if (args.spill_capacity_mb or args.spill_dir) and not args.sessions:
        ap.error("--spill-capacity-mb/--spill-dir tier per-SESSION slot "
                 "state; the uniform batch has no sessions to spill — "
                 "add --sessions N")
    if args.spill_dir and not args.spill_capacity_mb:
        ap.error("--spill-dir is the tier BELOW a bounded host-RAM store: "
                 "demotions to disk only happen when --spill-capacity-mb "
                 "caps the RAM tier, so without it the directory would "
                 "stay empty forever.  Size the cap in the layout's "
                 "PHYSICAL bytes — paged layouts spill only each "
                 "session's live pages and int8 snapshots stay "
                 "compressed, so one spilled session costs far less than "
                 "a dense max_len slot")
    if args.layout not in ("paged", "paged_int8"):
        return
    if cfg.attention_mode == "tconst" and cfg.arch_type not in \
            ("ssm", "audio"):
        # model-config check: pure-tconst KV is already O(1) — nothing
        # has a length axis, so the pool stores nothing and the knobs
        # are inert (tlin / dense-LM / enc-dec configs do page)
        print("[serve] note: pure tconst KV is O(1); the paged layout "
              "stores nothing in pages for this config (--page-size/"
              "--pool-pages are inert)")
    pages_per_slot = -(-max_len // args.page_size)
    slots = args.slots if args.sessions else args.batch
    full_pool = slots * pages_per_slot
    if not args.pool_pages:
        return                       # full pool: always valid, no allocator
    if args.pool_pages > full_pool:
        ap.error(
            f"--pool-pages {args.pool_pages} exceeds the full pool: "
            f"{slots} slots x {pages_per_slot} pages/slot "
            f"(max_len {max_len} / page {args.page_size}) = {full_pool} "
            f"pages — lower it or drop it for the full pool")
    if not args.sessions and args.pool_pages < full_pool:
        ap.error(
            f"--pool-pages {args.pool_pages} < full pool {full_pool} needs "
            f"the sessions-mode page allocator (uniform-batch prefill "
            f"cannot place rows in an under-sized pool); add --sessions N "
            f"or drop --pool-pages")
    # largest session this launcher will submit must be admissible
    worst_prompt = max(_session_prompt_lens(args)) if args.sessions \
        else args.prompt_len
    headroom = max(args.chunk, args.speculate + 1)
    worst_need = -(-(worst_prompt + args.gen + headroom)
                   // args.page_size)
    if worst_need > args.pool_pages:
        ap.error(
            f"--pool-pages {args.pool_pages} cannot admit the largest "
            f"session: prompt {worst_prompt} + gen {args.gen} + headroom "
            f"{headroom} needs {worst_need} pages of {args.page_size} "
            f"tokens — raise --pool-pages to >= {worst_need} or shrink "
            f"the sessions")


def build_mesh(ap, cfg, args):
    """Parse and validate ``--mesh DxM`` against the visible devices and
    the model config, so a bad geometry fails with a clear argparse
    error instead of a shape crash at first dispatch.  Returns the
    (data, model) Mesh, or None when --mesh is unset."""
    if not args.mesh:
        return None
    try:
        d_str, m_str = args.mesh.lower().split("x")
        d, m = int(d_str), int(m_str)
        if d < 1 or m < 1:
            raise ValueError
    except ValueError:
        ap.error(f"--mesh {args.mesh!r} must be DxM with positive "
                 f"integers, e.g. --mesh 2x4")
    n_dev = len(jax.devices())
    if d * m != n_dev:
        ap.error(
            f"--mesh {args.mesh}: axis product {d}x{m} = {d * m} must "
            f"equal the device count ({n_dev} visible); on CPU force "
            f"devices with XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={d * m}")
    if cfg.n_kv_heads > 1 and cfg.n_kv_heads % m != 0:
        # MQA (1 KV head) replicates its KV over model instead — exempt
        ap.error(
            f"--mesh {args.mesh}: model axis ({m}) must divide the KV "
            f"heads ({cfg.n_kv_heads}) — decode shards the KV head dim "
            f"over 'model' (try a model axis in "
            f"{[k for k in (1, 2, 4, 8) if cfg.n_kv_heads % k == 0]})")
    return make_decode_mesh(d, m)


def run_workload(cfg, api, params, args, max_len: int, mesh=None) -> int:
    """SLO-aware scheduling demo: replay a seeded traffic trace through
    the scheduler under a named policy and print the telemetry summary.

    The trace is a pure function of ``(spec, --seed)`` — rerunning with a
    different ``--policy`` replays the SAME sessions (same prompts,
    arrival chunks, SLO targets, per-session sampling seeds), so the
    printed TTFT / ITL / SLO-attainment numbers are directly comparable
    across policies.  Arrivals are denominated in scheduler chunks: the
    loop submits each session once the scheduler clock reaches its
    ``at_chunk``, then steps until every session drains."""
    spec = WorkloadSpec(
        n_sessions=args.sessions, vocab=cfg.vocab_size,
        arrival=args.workload, temperature=args.temperature,
        shared_frac=0.25 if args.prefix_sharing else 0.0,
        prefix_len=args.page_size if args.prefix_sharing else 16,
        repeat_frac=0.2, slo_frac=0.5,
        slo_ttft_chunks=args.slo_ttft_chunks)
    store = None
    if args.spill_capacity_mb:
        store = TierStore(
            capacity_bytes=int(args.spill_capacity_mb * (1 << 20)),
            spill_dir=args.spill_dir or None)
    decode = build_decode(cfg, _layout_spec(args),
                          prefill_chunk=args.prefill_chunk or None,
                          mesh=mesh)
    telemetry = ServingTelemetry()
    sched = SlotScheduler(decode, params, slots=args.slots,
                          max_len=max_len, chunk_size=args.chunk,
                          seed=args.seed,
                          prefix_sharing=args.prefix_sharing,
                          tier_store=store,
                          preempt_chunks=1 if store is not None else None,
                          policy=args.policy, telemetry=telemetry,
                          speculate=args.speculate, drafter=args.drafter)
    # leave headroom for the longest output draw (32) + one chunk of
    # over-generation so every generated session is admissible
    arrivals = generate_workload(
        spec, args.seed, max_prompt_len=max(8, max_len - 40 - args.chunk))

    t0 = time.time()
    i = 0
    while i < len(arrivals) or sched.pending or sched.active.any():
        while i < len(arrivals) and arrivals[i].at_chunk <= sched.clock:
            sched.submit(arrivals[i].session)
            i += 1
        sched.step()
        if sched.clock > 20_000:
            raise RuntimeError("workload did not drain within 20k chunks "
                               "— the scheduler is stuck")
    dt = time.time() - t0

    summary = telemetry.summary()
    total = summary["tokens_out"]
    print(f"[serve] arch={cfg.name} mode={cfg.attention_mode} "
          f"layout={sched.layout.name} workload={args.workload} "
          f"policy={args.policy} served {summary['sessions']} sessions "
          f"({total} tokens) on {args.slots} slots in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    print(json.dumps(summary, indent=2, sort_keys=True))
    ok = summary["finished"] == summary["sessions"]
    print(f"[serve] workload drained: {'ok' if ok else 'FAIL'} "
          f"(clock={sched.clock} chunks)")
    return 0 if ok else 1


def run_sessions(cfg, api, params, args, mesh=None) -> int:
    """Continuous-batching demo: N sessions with different prompt lengths
    admitted at staggered times into a fixed-slot batch; each streams its
    tokens and must match its own single-session generation."""
    rng = np.random.RandomState(args.seed)
    lens = _session_prompt_lens(args)
    if args.prefix_sharing:
        # shared system prompt + distinct tails: the prefix map stores
        # the common pages once, refcounted across sessions
        common = rng.randint(1, cfg.vocab_size,
                             size=args.prompt_len).astype(np.int32)
        prompts = [np.concatenate([common, rng.randint(
            1, cfg.vocab_size, size=n - args.prompt_len).astype(np.int32)])
            for n in lens]
    else:
        prompts = [rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)
                   for n in lens]

    store = None
    if args.spill_capacity_mb:
        store = TierStore(
            capacity_bytes=int(args.spill_capacity_mb * (1 << 20)),
            spill_dir=args.spill_dir or None)
    decode = build_decode(cfg, _layout_spec(args),
                          prefill_chunk=args.prefill_chunk or None,
                          mesh=mesh)
    telemetry = ServingTelemetry() if args.speculate else None
    sched = SlotScheduler(decode, params, slots=args.slots,
                          max_len=args.max_len or
                          (max(len(p) for p in prompts) + args.gen + 64),
                          chunk_size=args.chunk, seed=args.seed,
                          prefix_sharing=args.prefix_sharing,
                          tier_store=store,
                          preempt_chunks=1 if store is not None else None,
                          speculate=args.speculate, drafter=args.drafter,
                          telemetry=telemetry)

    def stream(sess, tok):
        print(f"[serve]   session {sess.sid}: token[{len(sess.tokens) - 1}]"
              f" = {tok}")

    t0 = time.time()
    sessions = []
    for i, p in enumerate(prompts):
        sessions.append(sched.submit(Session(
            p, max_new_tokens=args.gen,
            temperature=args.temperature,
            eos_id=args.eos if args.eos >= 0 else None,
            on_token=stream if args.verbose else None)))
        # staggered admission: run one chunk between submissions so slots
        # sit at different W_og resync phases.  Prefix sharing admits
        # everything up front instead — sessions in flight together keep
        # the shared prefix resident and refcounted.  Tiering also
        # submits up front: staggering drains the queue one session per
        # chunk, so the oversubscription the spill path exists for
        # would never build up.
        if not args.prefix_sharing and store is None:
            sched.step()
    if args.prefix_sharing:
        sched.admit_pending()
        if sched.prefix_sharing:
            refs = sched.page_refcounts()
            print(f"[serve] prefix sharing: {int((refs > 1).sum())} shared "
                  f"pages (refcount > 1), {int((refs > 0).sum())} assigned "
                  f"of {sched.layout.pool_pages} pool pages; assigned KV "
                  f"bytes (shared prefix counted once): "
                  f"{sched.assigned_kv_bytes()}")
        else:
            print("[serve] note: this config stores nothing in pages — "
                  "prefix sharing is inert (see the paged-layout note)")
    sched.run()
    dt = time.time() - t0

    total = sum(len(s.tokens) for s in sessions)
    print(f"[serve] arch={cfg.name} mode={cfg.attention_mode} "
          f"layout={sched.layout.name} "
          f"served {len(sessions)} sessions ({total} tokens) on "
          f"{args.slots} slots in {dt:.2f}s ({total / dt:.1f} tok/s)")
    chunks = [s for s in sched.stats if s.kind == "chunk"]
    if chunks:
        # compiled entries carry the one-time jit cost; report without them
        warm = [s.seconds for s in chunks if not s.compiled] or \
            [s.seconds for s in chunks]
        print(f"[serve] decode chunks: n={len(chunks)} "
              f"({args.chunk} tokens/dispatch, zero per-token host syncs) "
              f"median={np.median(warm) * 1e3:.2f}ms")
    if args.speculate and telemetry is not None:
        spec = telemetry.summary()["spec_decode"]
        if spec:
            rounds = [s for s in sched.stats if s.kind == "spec_chunk"]
            print(f"[serve] speculative ({args.drafter} drafter, "
                  f"k={args.speculate}): {spec['rounds']} verify rounds, "
                  f"acceptance {spec['acceptance_rate']:.2f} "
                  f"({spec['accepted']}/{spec['drafted']} draft tokens), "
                  f"{spec['tokens_per_round']:.2f} committed tokens per "
                  f"{args.speculate + 1}-token verify dispatch "
                  f"(n={len(rounds)} dispatches)")
    admits = [s.seconds for s in sched.admit_stats if not s.compiled]
    if admits:
        print(f"[serve] admissions: n={len(sched.admit_stats)} "
              f"warm median={np.median(admits) * 1e3:.2f}ms")
    if sched.prefill_chunk:
        tagged = sum(1 for s in sched.admit_stats if s.compiled)
        fwd = [s.forward_tokens for s in sched.admit_stats]
        print(f"[serve] chunked prefill (chunk={sched.prefill_chunk}): "
              f"forward tokens per admission {fwd} "
              f"(prompt lengths {[len(p) for p in prompts]}); "
              f"{tagged} compile-tagged admission(s) across "
              f"{len(set(len(p) for p in prompts))} distinct lengths")
    print(f"[serve] KV-cache bytes ({args.slots} slots, "
          f"{sched.layout.name} layout): {sched.kv_bytes()}")
    if mesh is not None:
        # global vs largest per-device shard — head-sharded fields split
        # over the model axis; greedy solo-run checks below run UNMESHED,
        # so a match is the meshed-vs-1-device stream identity.
        print(f"[serve] mesh {'x'.join(str(s) for s in mesh.devices.shape)}"
              f" ({mesh.devices.size} devices): per-device KV bytes "
              f"{sched.per_device_kv_bytes()} of {sched.kv_bytes()} global")

    ok = True
    if store is not None:
        sp = sched.spill_stats
        print(f"[serve] tiering: {sp['spills']} spills / {sp['resumes']} "
              f"resumes ({sp['spilled_bytes']} snapshot bytes through the "
              f"host tier); admission cache {sp['admit_store_hits']} hits "
              f"/ {sp['admit_store_puts']} puts; {sp['pages_retired']} "
              f"prefix pages retired / {sp['pages_readopted']} re-adopted")
        for s in sessions:
            print(f"[serve]   session {s.sid}: {s.spills} spills, "
                  f"{s.resumes} resumes")
        print(f"[serve] tiering: assigned device KV bytes "
              f"{sched.assigned_kv_bytes()} vs host tier: "
              f"{store.occupancy_bytes} RAM + {store.disk_bytes} disk "
              f"({len(store)} blobs; {store.stats})")
        if args.sessions > args.slots:
            need = args.sessions - args.slots
            cycles = sum(1 for s in sessions if s.resumes >= 1)
            cyc_ok = cycles >= need
            ok = ok and cyc_ok
            print(f"[serve] tiering: {cycles} session(s) completed >= 1 "
                  f"spill->resume cycle (oversubscribed by {need}): "
                  f"{'ok' if cyc_ok else 'FAIL'}")
    if args.temperature <= 0.0 and args.eos < 0:
        if args.layout in ("int8", "paged_int8"):
            print("[serve]   (int8 layouts: tokens may differ from the "
                  "dense solo run within the quantization tolerance — "
                  "skipping the exact-match check)")
        else:                         # greedy: must match solo runs
            eng = Engine(api, params, max_len=sched.max_len)
            for s, p in zip(sessions, prompts):
                ref = eng.generate({"tokens": jnp.asarray(p)[None]},
                                   args.gen)[0].tolist()
                match = s.tokens == ref
                ok = ok and match
                print(f"[serve]   session {s.sid} (prompt {len(p)}): "
                      f"{len(s.tokens)} tokens, matches solo run: {match}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tconst-41m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "paged", "int8", "paged_int8"],
                    help="physical cache layout behind the DecodeState "
                         "(paged_int8 = int8 pages in the shared pool, "
                         "scales in the page metadata)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per page (paged layout)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="total pages in the shared pool; 0 = full "
                         "slots*pages_per_slot (sessions mode can go "
                         "smaller — the scheduler allocates pages)")
    ap.add_argument("--eos", type=int, default=-1,
                    help="end-of-sequence token id for sessions mode "
                         "(< 0 disables early termination)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted content-addressed page sharing "
                         "(sessions mode, paged layouts): sessions get a "
                         "common system prompt whose pages are stored "
                         "once and mapped copy-on-write")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked KV-conditioned admission (sessions "
                         "mode): prefill prompts in fixed-size chunks of "
                         "N tokens (paged layouts: a page-size multiple) "
                         "so compiles are bounded by the chunk shape, "
                         "not the prompt length, and a prefix-shared "
                         "admission forwards only its unshared tail; "
                         "0 = one-shot full-prompt prefill")
    ap.add_argument("--workload", default="",
                    choices=["", "poisson", "bursty"],
                    help="replay a seeded traffic trace (sessions mode): "
                         "poisson or bursty arrivals, prompt/output "
                         "length mixes, a 50%% TTFT-SLO slice; prints "
                         "the telemetry summary (TTFT/ITL percentiles, "
                         "SLO attainment) instead of per-session streams")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "slo"],
                    help="admission/victim scheduling policy (workload "
                         "mode): fifo = arrival order; slo = deadline/"
                         "cost-aware (TTFT-slack admission ordering, "
                         "cheapest-victim spills)")
    ap.add_argument("--slo-ttft-chunks", type=int, default=8,
                    help="TTFT deadline (in scheduler chunks from "
                         "submission) carried by the workload's SLO "
                         "slice")
    ap.add_argument("--speculate", type=int, default=0,
                    help="speculative decoding (sessions mode): draft N "
                         "tokens per slot per tick and verify them in "
                         "one fixed-shape dispatch; streams stay token-"
                         "identical to the non-speculative run "
                         "(verify-exact acceptance); 0 disables")
    ap.add_argument("--drafter", default="ngram",
                    choices=["ngram", "tconst"],
                    help="draft proposer for --speculate: ngram = "
                         "self-drafting from the session's own token "
                         "window (zero model cost); tconst = a reduced "
                         "small-W tconst model with its own O(1) decode "
                         "state")
    ap.add_argument("--sessions", type=int, default=0,
                    help="serve N streaming sessions (staggered admission, "
                         "variable prompt lengths) instead of one batch")
    ap.add_argument("--slots", type=int, default=2,
                    help="scheduler decode slots (sessions mode)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode tokens per dispatch (sessions mode)")
    ap.add_argument("--verbose", action="store_true",
                    help="print every streamed token (sessions mode)")
    ap.add_argument("--spill-capacity-mb", type=float, default=0.0,
                    help="session tiering (sessions mode): host-RAM tier "
                         "store capacity in MiB for spilled slot "
                         "snapshots, retired prefix pages and admission "
                         "snapshots; oversubscribed sessions preempt-"
                         "spill at chunk boundaries and resume token-"
                         "identically; 0 disables tiering")
    ap.add_argument("--mesh", default="",
                    help="decode on a (data, model) device mesh, e.g. "
                         "--mesh 2x4: KV head dim shards over the model "
                         "axis, slot/batch dims over data; the SAME "
                         "decode path, token-identical to the 1-device "
                         "run (see docs/sharding.md; on CPU force "
                         "devices with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N)")
    ap.add_argument("--spill-dir", default="",
                    help="disk tier below the RAM store: entries evicted "
                         "from --spill-capacity-mb demote to this "
                         "directory (mmap'd .npy, durable across runs) "
                         "instead of being dropped")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.sessions:
        eff_max_len = args.max_len or \
            (max(_session_prompt_lens(args)) + args.gen + 64)
    else:
        eff_max_len = args.max_len or (args.prompt_len + args.gen + 64)
    validate_layout_args(ap, cfg, args, eff_max_len)
    mesh = build_mesh(ap, cfg, args)

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    if mesh is not None:
        # params replicate over the mesh (the decode step shards the KV
        # state, not the weights) — explicit placement keeps GSPMD from
        # re-deciding per dispatch
        params = jax.device_put(params, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()))

    if args.sessions:
        if args.workload:
            return run_workload(cfg, api, params, args, eff_max_len,
                                mesh=mesh)
        return run_sessions(cfg, api, params, args, mesh=mesh)

    max_len = args.max_len or (args.prompt_len + args.gen + 64)
    eng = Engine(api, params, max_len=max_len,
                 sample_temperature=args.temperature, seed=args.seed,
                 layout=_layout_spec(args), mesh=mesh)

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        Tv = cfg.frontend_tokens
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, Tv, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        batch["vision_mask"] = jnp.zeros(
            (args.batch, args.prompt_len), bool).at[:, :Tv].set(True)
    if cfg.is_encdec:
        batch["audio_feats"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.frontend_dim),
            jnp.dtype(cfg.dtype))

    t0 = time.time()
    out = eng.generate(batch, args.gen, record_stats=True)
    dt = time.time() - t0
    hits = [s.seconds for s in eng.stats if s.kind == "hit"]
    misses = [s.seconds for s in eng.stats if s.kind == "miss"]
    print(f"[serve] arch={cfg.name} mode={cfg.attention_mode} "
          f"layout={args.layout} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    if hits:
        print(f"[serve] cache-hit steps: n={len(hits)} "
              f"mean={np.mean(hits)*1e3:.2f}ms")
    if misses:
        print(f"[serve] cache-miss resyncs (compacted row-wise): "
              f"n={len(misses)} mean={np.mean(misses)*1e3:.2f}ms")
    print(f"[serve] KV-cache bytes @max_len ({args.layout} layout): "
          f"{eng.cache_bytes(args.batch)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

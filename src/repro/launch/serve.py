"""Serving launcher: batched generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch tconst-41m --reduced \\
      --prompt-len 64 --gen 64 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.serving.engine import Engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tconst-41m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))
    max_len = args.max_len or (args.prompt_len + args.gen + 64)
    eng = Engine(api, params, max_len=max_len,
                 sample_temperature=args.temperature, seed=args.seed)

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        Tv = cfg.frontend_tokens
        batch["vision_embeds"] = jnp.zeros(
            (args.batch, Tv, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        batch["vision_mask"] = jnp.zeros(
            (args.batch, args.prompt_len), bool).at[:, :Tv].set(True)
    if cfg.is_encdec:
        batch["audio_feats"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.frontend_dim),
            jnp.dtype(cfg.dtype))

    t0 = time.time()
    out = eng.generate(batch, args.gen, record_stats=True)
    dt = time.time() - t0
    hits = [s.seconds for s in eng.stats if s.kind == "hit"]
    misses = [s.seconds for s in eng.stats if s.kind == "miss"]
    print(f"[serve] arch={cfg.name} mode={cfg.attention_mode} "
          f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    if hits:
        print(f"[serve] cache-hit steps: n={len(hits)} "
              f"mean={np.mean(hits)*1e3:.2f}ms")
    if misses:
        print(f"[serve] cache-miss resyncs: n={len(misses)} "
              f"mean={np.mean(misses)*1e3:.2f}ms")
    print(f"[serve] KV-cache bytes @max_len: {eng.cache_bytes(args.batch)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
is a STUB: ``input_specs`` supplies post-conv frame embeddings of shape
(B, encoder_seq, frontend_dim); a learned projector maps them to d_model.

Deviations from the original (documented in DESIGN.md): decoder
self-attention uses RoPE instead of learned absolute positions so that the
assigned decode shapes (32k / 524k) are well-defined; norms are LayerNorm
and FFNs GELU, as in the original.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import attention as A
from repro.layers import embed as E
from repro.layers import rope as R
from repro.layers.common import (Params, embed_init, init_layernorm,
                                 layernorm, split_keys)
from repro.layers.mlp import gelu_mlp, init_gelu_mlp
from repro.kernels.xla_flash import flash_attention

FLASH_THRESHOLD = 2048


def _init_enc_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, kf = split_keys(key, 2)
    return {
        "ln1": init_layernorm(cfg.d_model, cfg.param_dtype),
        "attn": A.init_attention(ka, cfg),
        "ln2": init_layernorm(cfg.d_model, cfg.param_dtype),
        "ffn": init_gelu_mlp(kf, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def _init_dec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, kc, kf = split_keys(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model, cfg.param_dtype),
        "attn": A.init_attention(ka, cfg),
        "lnc": init_layernorm(cfg.d_model, cfg.param_dtype),
        "cross": A.init_attention(kc, cfg),
        "ln2": init_layernorm(cfg.d_model, cfg.param_dtype),
        "ffn": init_gelu_mlp(kf, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_encdec(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kp, kenc, kdec = split_keys(key, 4)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": E.init_embed(ke, cfg),
        "enc_pos": embed_init(kp, (cfg.encoder_seq, cfg.d_model),
                              cfg.param_dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": init_layernorm(cfg.d_model, cfg.param_dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_norm": init_layernorm(cfg.d_model, cfg.param_dtype),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params: Params, audio_feats: jax.Array, cfg: ModelConfig
           ) -> jax.Array:
    """audio_feats: (B, T_enc, frontend_dim) stub conv-frontend output."""
    from repro.sharding.rules import shard_act
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    x = E.project_frontend(params["embed"], audio_feats.astype(dtype))
    x = x + params["enc_pos"].astype(dtype)[None, :x.shape[1]]
    x = shard_act(x)

    def body(x, layer):
        x = shard_act(x)
        xn = layernorm(layer["ln1"], x, eps)
        o = A.attention_block(layer["attn"], xn, xn, None)   # bidirectional
        x = x + o
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, None

    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm(params["enc_norm"], x, eps)


# ---------------------------------------------------------------------------
# Decoder (teacher-forced)
# ---------------------------------------------------------------------------


def _dec_self_attn(layer: Params, xn: jax.Array, pos: jax.Array,
                   cfg: ModelConfig, cos, sin) -> jax.Array:
    dtype = xn.dtype
    q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
    q = R.apply_rope(q, cos, sin)
    k = R.apply_rope(k, cos, sin)
    L = xn.shape[1]
    window = cfg.sliding_window if cfg.attention_mode == "sliding" else 0
    if L >= FLASH_THRESHOLD:
        o = flash_attention(q, k, v, pos, pos, window, True, 0.0, 512, 512)
    else:
        mode = "sliding" if window else "causal"
        o = A.sdpa(q, k, v, A.make_mask(pos, pos, mode, window))
    return A.out_proj(layer["attn"], o, dtype)


def _cross_attn(layer: Params, xc: jax.Array, memory: jax.Array) -> jax.Array:
    """Decoder->encoder cross-attention; blocked path for long decoders
    (naive logits are (B, H, L_dec, T_enc) — 63 GiB at train_4k x B=256)."""
    L = xc.shape[1]
    if L < FLASH_THRESHOLD:
        return A.attention_block(layer["cross"], xc, memory, None)
    dtype = xc.dtype
    q, k, v = A.qkv_proj(layer["cross"], xc, memory, dtype)
    qp = jnp.arange(L, dtype=jnp.int32)
    kp = jnp.arange(memory.shape[1], dtype=jnp.int32)
    o = flash_attention(q, k, v, qp, kp, 0, False, 0.0, 512, 512)
    return A.out_proj(layer["cross"], o, dtype)


def decode_train(params: Params, tokens: jax.Array, memory: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    """Teacher-forced decoder. tokens (B, L), memory (B, T_enc, D)."""
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B, L = tokens.shape
    x = E.embed_tokens(params["embed"], tokens, dtype)
    pos = jnp.arange(L, dtype=jnp.int32)
    cos, sin = R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, layer):
        from repro.sharding.rules import shard_act
        x = shard_act(x)
        xn = layernorm(layer["ln1"], x, eps)
        x = x + _dec_self_attn(layer, xn, pos, cfg, cos, sin)
        xc = layernorm(layer["lnc"], x, eps)
        x = x + _cross_attn(layer, xc, memory)
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, None

    body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = layernorm(params["dec_norm"], x, eps)
    return E.lm_head(params["embed"], x)


def encdec_forward(params: Params, tokens: jax.Array, audio_feats: jax.Array,
                   cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    memory = encode(params, audio_feats, cfg)
    logits = decode_train(params, tokens, memory, cfg)
    return logits, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


# Cache partition for the serving layer (repro.models.api.DecodeState):
# true KV cache vs bookkeeping, and the batch ("slot") axis of each entry.
KV_KEYS = ("k", "v", "cross_k", "cross_v")
CACHE_BATCH_AXES = {"len": 0, "done": 0, "k": 1, "v": 1,
                    "cross_k": 1, "cross_v": 1}

# Cache-layout metadata (repro.models.layouts): the decoder self-attention
# KV grows with max_len (paged); the cross K/V is fixed encoder_seq and
# stays dense.  All four are quantizable.
LENGTH_AXES = {"k": 2, "v": 2}
QUANT_FIELDS = KV_KEYS


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int
                      ) -> Dict[str, Any]:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    n = cfg.n_layers
    return {
        "len": jnp.zeros((batch,), jnp.int32),
        "done": jnp.zeros((batch,), bool),
        "k": jnp.zeros((n, batch, max_len, kv, hd), dt),
        "v": jnp.zeros((n, batch, max_len, kv, hd), dt),
        "cross_k": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd), dt),
        "cross_v": jnp.zeros((n, batch, cfg.encoder_seq, kv, hd), dt),
    }


def encdec_prefill(params: Params, tokens: jax.Array, audio_feats: jax.Array,
                   cfg: ModelConfig, max_len: int
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Encode audio, pre-project per-layer cross K/V, teacher-force the
    prompt through the decoder, fill self-attention caches."""
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B, L = tokens.shape
    memory = encode(params, audio_feats, cfg)
    cache = init_encdec_cache(cfg, B, max_len)
    x = E.embed_tokens(params["embed"], tokens, dtype)
    pos = jnp.arange(L, dtype=jnp.int32)
    cos, sin = R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, layer):
        xn = layernorm(layer["ln1"], x, eps)
        q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k = R.apply_rope(k, cos, sin)
        o = A.sdpa(q, k, v, A.make_mask(pos, pos, "causal")) \
            if L < FLASH_THRESHOLD else flash_attention(
                q, k, v, pos, pos, 0, True, 0.0, 512, 512)
        x = x + A.out_proj(layer["attn"], o, dtype)
        kf = jnp.zeros((B, max_len) + k.shape[2:], dtype)
        vf = jnp.zeros((B, max_len) + v.shape[2:], dtype)
        kf = jax.lax.dynamic_update_slice_in_dim(kf, k, 0, 1)
        vf = jax.lax.dynamic_update_slice_in_dim(vf, v, 0, 1)
        ck, cv = A.project_kv(layer["cross"], memory)
        xc = layernorm(layer["lnc"], x, eps)
        x = x + A.attention_block(layer["cross"], xc, memory, None)
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, {"k": kf, "v": vf, "cross_k": ck, "cross_v": cv}

    x, extras = jax.lax.scan(body, x, params["dec_layers"])
    for key, val in extras.items():
        cache[key] = val
    x = layernorm(params["dec_norm"], x, eps)
    logits = E.lm_head(params["embed"], x[:, -1:])[:, 0]
    cache["len"] = jnp.full((B,), L, jnp.int32)
    return logits, cache


def encdec_seed_cache(params: Params, audio_feats: jax.Array,
                      cfg: ModelConfig, max_len: int) -> Dict[str, Any]:
    """Seed step of the chunked prefill: run the encoder ONCE (fixed
    ``encoder_seq`` shape — one compile regardless of prompt length) and
    pre-project the per-layer cross K/V the decoder chunks attend.  The
    decoder self-attention KV starts empty and is filled chunk by
    chunk."""
    memory = encode(params, audio_feats, cfg)
    cache = init_encdec_cache(cfg, audio_feats.shape[0], max_len)

    def body(_, layer):
        return None, A.project_kv(layer["cross"], memory)

    _, (ck, cv) = jax.lax.scan(body, None, params["dec_layers"])
    cache["cross_k"], cache["cross_v"] = ck, cv
    return cache


def encdec_prefill_chunk(params: Params, row: Dict[str, Any],
                         tokens: jax.Array, start: jax.Array,
                         n_valid: jax.Array, cfg: ModelConfig
                         ) -> Tuple[jax.Array, Dict[str, Any],
                                    Dict[str, Any]]:
    """One fixed-shape chunk of the chunked decoder prefill: the chunk's
    C queries self-attend the row cache's resident positions [0, start)
    plus the chunk (causal, true positions — matches the one-shot
    :func:`encdec_prefill` up to float association) and cross-attend the
    pre-projected encoder memory from :func:`encdec_seed_cache`.
    Returns (logits (B, C, V), row, chunk_kv)."""
    from repro.kernels import ops
    del n_valid              # no recurrent state; padding is causally dead
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B, C = tokens.shape
    x = E.embed_tokens(params["embed"], tokens, dtype)
    pos = start + jnp.arange(C, dtype=jnp.int32)
    cos, sin = R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, xs):
        layer, k_row, v_row, ck, cv = xs
        xn = layernorm(layer["ln1"], x, eps)
        q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k = R.apply_rope(k, cos, sin)
        k_row = jax.lax.dynamic_update_slice_in_dim(
            k_row, k.astype(k_row.dtype), start, axis=1)
        v_row = jax.lax.dynamic_update_slice_in_dim(
            v_row, v.astype(v_row.dtype), start, axis=1)
        kpos = jnp.arange(k_row.shape[1], dtype=jnp.int32)
        # plain causal, like the one-shot prefill's teacher-forced pass
        o = ops.prefill_chunk_attention(q, k_row, v_row, pos, kpos, 0, 0.0)
        x = x + A.out_proj(layer["attn"], o, dtype)
        xc = layernorm(layer["lnc"], x, eps)
        x = x + A.cross_attend_cached(layer["cross"], xc, ck, cv, None)
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, (k_row, v_row, k, v)

    x, (k_rows, v_rows, kc, vc) = jax.lax.scan(
        body, x, (params["dec_layers"], row["k"], row["v"],
                  row["cross_k"], row["cross_v"]))
    row = dict(row)
    row["k"], row["v"] = k_rows, v_rows
    x = layernorm(params["dec_norm"], x, eps)
    logits = E.lm_head(params["embed"], x)
    return logits, row, {"k": kc, "v": vc}


def encdec_decode_step_views(params: Params, cache: Dict[str, Any],
                             token: jax.Array, cfg: ModelConfig
                             ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Layout-native one-token decode: KV names in ``cache`` are
    ``repro.models.layouts`` FieldViews.  The growing decoder KV (paged /
    int8) is appended and attended in its physical representation; the
    fixed-size cross K/V is read through its view (int8-capable).
    token: (B,) -> (logits (B, V), cache)."""
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    x = E.embed_tokens(params["embed"], token[:, None], dtype)
    pos = cache["len"][:, None]
    cos, sin = R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
    window = cfg.sliding_window if cfg.attention_mode == "sliding" else 0

    def body(i, carry):
        x, k_all, v_all = carry
        layer = jax.tree_util.tree_map(lambda a: a[i], params["dec_layers"])
        xn = layernorm(layer["ln1"], x, eps)
        out, kv, vv = A.decode_attend_view(
            layer["attn"], xn, k_all.layer(i), v_all.layer(i),
            cache["len"], cos, sin, 0.0, window)
        x = x + out
        xc = layernorm(layer["lnc"], x, eps)
        x = x + A.cross_attend_view(
            layer["cross"], xc, cache["cross_k"].layer(i),
            cache["cross_v"].layer(i), None)
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, k_all.set_layer(i, kv), v_all.set_layer(i, vv)

    x, k_all, v_all = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    cache = dict(cache)
    cache["k"], cache["v"] = k_all, v_all
    x = layernorm(params["dec_norm"], x, eps)
    logits = E.lm_head(params["embed"], x)[:, 0]
    cache["len"] = cache["len"] + 1
    return logits, cache


def encdec_verify_chunk_views(params: Params, cache: Dict[str, Any],
                              feed: jax.Array, cfg: ModelConfig
                              ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Speculative VERIFY: score C fed decoder tokens per slot in one
    fixed-shape dispatch (:func:`encdec_decode_step_views` with the
    C-step loop collapsed into one chunk attention per layer).  The
    C keys/values land at decoder positions ``len + c`` through the
    views; ``len`` is NOT advanced — acceptance is a later ``len += m``
    and the rejected suffix is causally masked stale garbage.  The
    frozen cross K/V is read via C-query cross-attention.
    Returns (logits (B, C, V), cache — counters untouched)."""
    from repro.kernels import ops
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    B, C = feed.shape
    x = E.embed_tokens(params["embed"], feed, dtype)             # (B, C, D)
    pos = cache["len"][:, None] + \
        jnp.arange(C, dtype=jnp.int32)[None]                     # (B, C)
    cos, sin = R.rope_cos_sin(pos, cfg.resolved_head_dim, cfg.rope_theta)
    window = cfg.sliding_window if cfg.attention_mode == "sliding" else 0

    def body(i, carry):
        x, k_all, v_all = carry
        layer = jax.tree_util.tree_map(lambda a: a[i], params["dec_layers"])
        xn = layernorm(layer["ln1"], x, eps)
        q, k_new, v_new = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k_new = R.apply_rope(k_new, cos, sin)
        kv, vv = k_all.layer(i), v_all.layer(i)
        for c in range(C):
            kv = kv.write_token(cache["len"] + c, k_new[:, c])
            vv = vv.write_token(cache["len"] + c, v_new[:, c])
        kd = kv.dense().astype(dtype)
        kpos = jnp.arange(kd.shape[1], dtype=jnp.int32)
        o = ops.prefill_chunk_attention(q, kd, vv.dense().astype(dtype),
                                        pos, kpos, window, 0.0)
        x = x + A.out_proj(layer["attn"], o, dtype)
        xc = layernorm(layer["lnc"], x, eps)
        x = x + A.verify_attend_view(
            layer["cross"], xc, cache["cross_k"].layer(i),
            cache["cross_v"].layer(i), None)
        x = x + gelu_mlp(layer["ffn"], layernorm(layer["ln2"], x, eps))
        return x, k_all.set_layer(i, kv), v_all.set_layer(i, vv)

    x, k_all, v_all = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]))
    cache = dict(cache)
    cache["k"], cache["v"] = k_all, v_all
    x = layernorm(params["dec_norm"], x, eps)
    logits = E.lm_head(params["embed"], x)                       # (B, C, V)
    return logits, cache


def encdec_decode_step(params: Params, cache: Dict[str, Any],
                       token: jax.Array, cfg: ModelConfig
                       ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Dense-dict one-token decode: legacy entry point / parity oracle."""
    from repro.models import layouts as LT
    views = {k: LT.DenseView(v, CACHE_BATCH_AXES[k]) if k in KV_KEYS else v
             for k, v in cache.items()}
    logits, out = encdec_decode_step_views(params, views, token, cfg)
    return logits, {k: v.dense() if isinstance(v, LT.FieldView) else v
                    for k, v in out.items()}

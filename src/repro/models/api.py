"""Unified model facade + the decode-side inference protocol.

Two surfaces live here:

* :class:`ModelAPI` — the training facade (init / forward / loss) plus
  thin compatibility wrappers for the legacy decode entry points
  (``init_cache`` / ``prefill`` / ``decode_step`` / ``resync``) used by
  the dry-run launcher and the complexity benchmarks.

* :class:`DecodeAPI` — the serving protocol.  A decode cache is a typed
  :class:`DecodeState` (registered pytree) with an explicit ``kv`` vs
  ``bookkeeping`` partition, so cache-size reporting (paper Fig 8g)
  reads the partition instead of guessing from field names.  The
  protocol is slot-oriented for continuous batching:

    ``init_state(slots, max_len)``          fixed-shape multi-slot state
    ``prefill_into_slot(params, state, slot, tokens)``
                                            admit one request mid-flight
    ``step(params, state, token)``          one batched token, with the
                                            W_og resync fused on-device
                                            (``lax.cond`` on per-slot
                                            phase counters — no host
                                            round-trip)
    ``maybe_sync(params, state)``           the fused sync, standalone

  :func:`decode_chunk` scans ``step`` so a k-token decode chunk runs as
  ONE dispatch with zero per-token host syncs.  Implementations exist
  for the TConst core, the dense LM family, and the encoder-decoder.

Every entry point takes/returns plain pytrees so the launchers can jit
them with explicit shardings.  ``input_specs`` produces the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.core import tconst as TC
from repro.models import encdec as ED
from repro.models import lm as LM


def _is_tconst(cfg: ModelConfig) -> bool:
    return cfg.attention_mode in ("tconst", "tlin") and \
        cfg.arch_type not in ("ssm", "audio")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE.  logits (B, L, V) f32; targets (B, L) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# DecodeState: the typed decode cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class DecodeState:
    """Decode-side cache with an explicit kv / bookkeeping partition.

    ``kv`` holds the true KV (and recurrent-state) buffers — the bytes
    reported for paper Fig 8g.  ``bookkeeping`` holds token-id buffers,
    lengths and per-slot phase counters, which are NOT KV cache.
    ``axes`` (static aux data) maps every field to its batch ("slot")
    axis so the serving layer can scatter a prefilled row into a slot
    and row-select at resync boundaries without knowing model layouts.
    """

    kv: Dict[str, jax.Array]
    bookkeeping: Dict[str, jax.Array]
    axes: Dict[str, int]

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("kv"), self.kv),
            (jax.tree_util.GetAttrKey("bookkeeping"), self.bookkeeping),
        )
        return children, tuple(sorted(self.axes.items()))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kv, bookkeeping = children
        return cls(kv, bookkeeping, dict(aux))

    # -- construction -------------------------------------------------------
    @classmethod
    def from_cache(cls, cache: Dict[str, Any], kv_keys: Tuple[str, ...],
                   axes: Dict[str, int]) -> "DecodeState":
        kv = {k: v for k, v in cache.items() if k in kv_keys}
        bk = {k: v for k, v in cache.items() if k not in kv_keys}
        return cls(kv, bk, {k: axes[k] for k in cache})

    def merged(self) -> Dict[str, Any]:
        return {**self.bookkeeping, **self.kv}

    # -- accounting ---------------------------------------------------------
    def kv_bytes(self) -> int:
        """KV-cache footprint from the explicit partition (works on real
        arrays and on ShapeDtypeStructs from ``jax.eval_shape``)."""
        return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(self.kv))

    @property
    def slots(self) -> int:
        name, leaf = next(iter(sorted(self.bookkeeping.items())))
        return leaf.shape[self.axes[name]]

    # -- slot surgery -------------------------------------------------------
    def _map2(self, other: "DecodeState", fn) -> "DecodeState":
        kv = {k: fn(k, self.kv[k], other.kv[k]) for k in self.kv}
        bk = {k: fn(k, self.bookkeeping[k], other.bookkeeping[k])
              for k in self.bookkeeping}
        return DecodeState(kv, bk, self.axes)

    def with_slot(self, slot: jax.Array, row: "DecodeState") -> "DecodeState":
        """Scatter a single-row state (batch size 1) into slot ``slot``."""
        def upd(name, dst, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis=self.axes[name])
        return self._map2(row, upd)

    def where_rows(self, rows: jax.Array, other: "DecodeState"
                   ) -> "DecodeState":
        """Per-slot select: take self where ``rows`` (B,) is True, else
        ``other``.  Used to freeze inactive slots inside a decode chunk."""
        from repro.layers.common import where_rows
        return self._map2(
            other, lambda name, a, b: where_rows(rows, a, b,
                                                 self.axes[name]))


# ---------------------------------------------------------------------------
# Sampling + chunked decode (zero per-token host syncs)
# ---------------------------------------------------------------------------


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-slot sampling.  logits (B, V); temperature (B,) with <= 0
    meaning greedy.  Pure device code — safe inside a scanned step."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature, 1e-6)
    sampled = jax.random.categorical(
        key, logits / t[:, None], axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def decode_chunk(decode: "DecodeAPI", params: Any, state: DecodeState,
                 token: jax.Array, key: jax.Array, temperature: jax.Array,
                 active: jax.Array, n_steps: int
                 ) -> Tuple[jax.Array, DecodeState, jax.Array]:
    """Run ``n_steps`` decode steps as ONE ``lax.scan`` — a single
    dispatch, zero per-token host round-trips.  The W_og resync fires
    inside the scanned step via ``lax.cond`` (see ``DecodeAPI.step``),
    correct per-slot even when slots sit at different phases.

    token: (B,) the token each slot feeds at the first step (its last
    sampled token).  active: (B,) bool; inactive slots are frozen
    bit-identically and keep echoing their input token.  Returns
    (sampled tokens (B, n_steps), state, key).
    """
    def body(carry, _):
        state, tok, key = carry
        logits, new_state = decode.step(params, state, tok)
        key, sub = jax.random.split(key)
        nxt = sample_tokens(logits, temperature, sub)
        nxt = jnp.where(active, nxt, tok)
        new_state = new_state.where_rows(active, state)
        return (new_state, nxt, key), nxt

    (state, _, key), toks = jax.lax.scan(
        body, (state, token, key), None, length=n_steps)
    toks = jnp.moveaxis(toks, 0, 1) if n_steps else \
        jnp.zeros((token.shape[0], 0), jnp.int32)
    return toks, state, key


# ---------------------------------------------------------------------------
# DecodeAPI protocol + per-family implementations
# ---------------------------------------------------------------------------


class DecodeAPI:
    """Slot-oriented decode protocol (see module docstring).

    All methods are pure jax functions of their array arguments, so the
    serving layer can jit them (``step`` composes into
    :func:`decode_chunk`'s scan).  ``raw_step`` / ``sync`` /
    ``needs_sync`` are the un-fused pieces used by the instrumented
    engine path that times cache hits and misses separately (Fig 8).
    """

    cfg: ModelConfig

    # required surface ------------------------------------------------------
    def init_state(self, slots: int, max_len: int) -> DecodeState:
        raise NotImplementedError

    def prefill(self, params, batch: Dict[str, Any], max_len: int
                ) -> Tuple[jax.Array, DecodeState]:
        """Full-batch prefill (all slots, same-length prompts)."""
        raise NotImplementedError

    def prefill_into_slot(self, params, state: DecodeState, slot: jax.Array,
                          tokens: jax.Array,
                          extras: Optional[Dict[str, Any]] = None
                          ) -> Tuple[jax.Array, DecodeState]:
        """Admit one request: prefill prompt ``tokens`` (L,) and scatter
        the resulting row into ``slot``.  Returns (logits (V,), state)."""
        raise NotImplementedError

    def raw_step(self, params, state: DecodeState, token: jax.Array
                 ) -> Tuple[jax.Array, DecodeState]:
        """One cache-hit decode step, NO sync check (instrumentation)."""
        raise NotImplementedError

    # sync surface (identity for models without periodic resync) ------------
    def needs_sync(self, state: DecodeState) -> jax.Array:
        return jnp.zeros((state.slots,), bool)

    def sync(self, params, state: DecodeState) -> DecodeState:
        return state

    def maybe_sync(self, params, state: DecodeState) -> DecodeState:
        return state

    # fused step ------------------------------------------------------------
    def step(self, params, state: DecodeState, token: jax.Array
             ) -> Tuple[jax.Array, DecodeState]:
        """maybe_sync + raw_step: the unit scanned by decode_chunk."""
        return self.raw_step(params, self.maybe_sync(params, state), token)


@dataclasses.dataclass(frozen=True)
class TConstDecode(DecodeAPI):
    """Paper §4 serving: O(1) cache-hit steps, periodic O(N) resync.

    The resync decision lives ON DEVICE: ``step`` checks the per-slot
    ``gen_len`` phase counters and runs the W_og-boundary global
    synchronisation through ``lax.cond``, applied row-selectively so
    slots admitted at different times stay token-for-token identical to
    their solo runs (mode="tlin" keeps the O(N) history KV per block).
    """

    cfg: ModelConfig

    @property
    def mode(self) -> str:
        return self.cfg.attention_mode

    def _wrap(self, cache: Dict[str, Any]) -> DecodeState:
        return DecodeState.from_cache(cache, TC.KV_KEYS, TC.CACHE_BATCH_AXES)

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap(
            TC.init_tconst_cache(self.cfg, slots, max_len, self.mode))

    def prefill(self, params, batch, max_len):
        logits, cache = TC.prefill(params, batch["tokens"], self.cfg,
                                   max_len, mode=self.mode)
        return logits, self._wrap(cache)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None):
        max_len = state.bookkeeping["tokens"].shape[1]
        logits, row = TC.prefill(params, tokens[None], self.cfg, max_len,
                                 mode=self.mode)
        return logits[0], state.with_slot(slot, self._wrap(row))

    def raw_step(self, params, state, token):
        logits, cache = TC.decode_step(params, state.merged(), token,
                                       self.cfg, mode=self.mode)
        return logits, self._wrap(cache)

    def needs_sync(self, state):
        return TC.needs_resync(state.merged(), self.cfg)

    def sync(self, params, state):
        cache = state.merged()
        rows = TC.needs_resync(cache, self.cfg)
        return self._wrap(
            TC.resync_rows(params, cache, self.cfg, rows, self.mode))

    def maybe_sync(self, params, state):
        return self._wrap(
            TC.maybe_resync(params, state.merged(), self.cfg, self.mode))


@dataclasses.dataclass(frozen=True)
class DenseDecode(DecodeAPI):
    """Decoder-only LM family (dense / moe / ssm / hybrid / vlm): a
    conventional growing KV cache (or O(1) recurrent state for ssm),
    no periodic sync."""

    cfg: ModelConfig

    def _wrap(self, cache: Dict[str, Any]) -> DecodeState:
        return DecodeState.from_cache(cache, LM.KV_KEYS, LM.CACHE_BATCH_AXES)

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap(LM.init_kv_cache(self.cfg, slots, max_len))

    def _max_len(self, state: DecodeState, fallback: int) -> int:
        for key in ("k", "dense_k"):
            if key in state.kv:
                return state.kv[key].shape[2]
        return fallback                      # pure ssm: no positional buffer

    def prefill(self, params, batch, max_len):
        logits, cache = LM.lm_prefill(
            params, batch["tokens"], self.cfg, max_len,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))
        return logits, self._wrap(cache)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None):
        extras = extras or {}
        max_len = self._max_len(state, tokens.shape[0])
        logits, cache = LM.lm_prefill(
            params, tokens[None], self.cfg, max_len,
            vision_embeds=None if "vision_embeds" not in extras else
            extras["vision_embeds"][None],
            vision_mask=None if "vision_mask" not in extras else
            extras["vision_mask"][None])
        return logits[0], state.with_slot(slot, self._wrap(cache))

    def raw_step(self, params, state, token):
        logits, cache = LM.lm_decode_step(params, state.merged(), token,
                                          self.cfg)
        return logits, self._wrap(cache)


@dataclasses.dataclass(frozen=True)
class EncDecDecode(DecodeAPI):
    """Encoder-decoder: per-session encoder memory is pre-projected into
    the per-layer cross K/V cache at admission."""

    cfg: ModelConfig

    def _wrap(self, cache: Dict[str, Any]) -> DecodeState:
        return DecodeState.from_cache(cache, ED.KV_KEYS, ED.CACHE_BATCH_AXES)

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap(ED.init_encdec_cache(self.cfg, slots, max_len))

    def prefill(self, params, batch, max_len):
        logits, cache = ED.encdec_prefill(params, batch["tokens"],
                                          batch["audio_feats"], self.cfg,
                                          max_len)
        return logits, self._wrap(cache)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None):
        if not extras or "audio_feats" not in extras:
            raise ValueError(
                "encoder-decoder sessions need extras={'audio_feats': "
                "(T_enc, frontend_dim)} at submission")
        max_len = state.kv["k"].shape[2]
        logits, cache = ED.encdec_prefill(
            params, tokens[None], extras["audio_feats"][None], self.cfg,
            max_len)
        return logits[0], state.with_slot(slot, self._wrap(cache))

    def raw_step(self, params, state, token):
        logits, cache = ED.encdec_decode_step(params, state.merged(), token,
                                              self.cfg)
        return logits, self._wrap(cache)


def build_decode(cfg: ModelConfig) -> DecodeAPI:
    if _is_tconst(cfg):
        return TConstDecode(cfg)
    if cfg.is_encdec:
        return EncDecDecode(cfg)
    return DenseDecode(cfg)


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def init(self, key: jax.Array):
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.init_tconst_lm(key, cfg)
        if cfg.is_encdec:
            return ED.init_encdec(key, cfg)
        return LM.init_lm(key, cfg)

    # -- training -----------------------------------------------------------
    def forward(self, params, batch: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        if _is_tconst(cfg):
            return TC.tconst_forward(params, tokens, cfg,
                                     mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.encdec_forward(params, tokens, batch["audio_feats"],
                                     cfg)
        return LM.lm_forward(
            params, tokens, cfg,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))

    def loss(self, params, batch: Dict[str, Any]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        ce = cross_entropy(logits[:, :-1], tokens[:, 1:])
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving (compat wrappers over DecodeAPI; cache is a DecodeState) ---
    @property
    def decode(self) -> DecodeAPI:
        return build_decode(self.cfg)

    def init_cache(self, batch: int, max_len: int) -> DecodeState:
        return self.decode.init_state(batch, max_len)

    def prefill(self, params, batch: Dict[str, Any], max_len: int
                ) -> Tuple[jax.Array, DecodeState]:
        return self.decode.prefill(params, batch, max_len)

    def decode_step(self, params, state: DecodeState, token: jax.Array
                    ) -> Tuple[jax.Array, DecodeState]:
        return self.decode.raw_step(params, state, token)

    def resync(self, params, state: DecodeState) -> DecodeState:
        """TConst periodic global synchronisation — full, all-rows
        (the legacy schedule where every row shares one phase)."""
        cfg = self.cfg
        if _is_tconst(cfg):
            cache = TC.resync(params, state.merged(), cfg,
                              mode=cfg.attention_mode)
            return DecodeState.from_cache(cache, TC.KV_KEYS,
                                          TC.CACHE_BATCH_AXES)
        return state

    def needs_resync(self, state: DecodeState) -> jax.Array:
        if _is_tconst(self.cfg):
            return self.decode.needs_sync(state)
        return jnp.zeros((), bool)

    # -- dry-run specs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (assignment: weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        specs: Dict[str, Any] = {"tokens": f((B, L), jnp.int32)}
        if cfg.arch_type == "vlm":
            Tv = cfg.frontend_tokens
            specs["vision_embeds"] = f((B, Tv, cfg.frontend_dim),
                                       jnp.dtype(cfg.dtype))
            specs["vision_mask"] = f((B, L), jnp.bool_)
        if cfg.is_encdec:
            specs["audio_feats"] = f((B, cfg.encoder_seq, cfg.frontend_dim),
                                     jnp.dtype(cfg.dtype))
        return specs

    def cache_specs(self, batch: int, max_len: int) -> DecodeState:
        """ShapeDtypeStructs of the serve cache (eval_shape: no alloc)."""
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len))


def build_model(cfg: ModelConfig) -> ModelAPI:
    cfg.validate()
    return ModelAPI(cfg)

"""Unified model facade: one API per architecture, dispatching to the
decoder-only LM, the encoder-decoder, or the TConstFormer core.

Every entry point takes/returns plain pytrees so the launchers can jit
them with explicit shardings.  ``input_specs`` produces the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.core import tconst as TC
from repro.models import encdec as ED
from repro.models import lm as LM


def _is_tconst(cfg: ModelConfig) -> bool:
    return cfg.attention_mode in ("tconst", "tlin") and \
        cfg.arch_type not in ("ssm", "audio")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE.  logits (B, L, V) f32; targets (B, L) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def init(self, key: jax.Array):
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.init_tconst_lm(key, cfg)
        if cfg.is_encdec:
            return ED.init_encdec(key, cfg)
        return LM.init_lm(key, cfg)

    # -- training -----------------------------------------------------------
    def forward(self, params, batch: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        if _is_tconst(cfg):
            return TC.tconst_forward(params, tokens, cfg,
                                     mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.encdec_forward(params, tokens, batch["audio_feats"],
                                     cfg)
        return LM.lm_forward(
            params, tokens, cfg,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))

    def loss(self, params, batch: Dict[str, Any]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        ce = cross_entropy(logits[:, :-1], tokens[:, 1:])
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.init_tconst_cache(cfg, batch, max_len,
                                        mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.init_encdec_cache(cfg, batch, max_len)
        return LM.init_kv_cache(cfg, batch, max_len)

    def prefill(self, params, batch: Dict[str, Any], max_len: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        if _is_tconst(cfg):
            return TC.prefill(params, tokens, cfg, max_len,
                              mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.encdec_prefill(params, tokens, batch["audio_feats"],
                                     cfg, max_len)
        return LM.lm_prefill(
            params, tokens, cfg, max_len,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))

    def decode_step(self, params, cache, token: jax.Array):
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.decode_step(params, cache, token, cfg,
                                  mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.encdec_decode_step(params, cache, token, cfg)
        return LM.lm_decode_step(params, cache, token, cfg)

    def resync(self, params, cache):
        """TConst periodic global synchronisation (no-op otherwise)."""
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.resync(params, cache, cfg, mode=cfg.attention_mode)
        return cache

    def needs_resync(self, cache) -> jax.Array:
        if _is_tconst(self.cfg):
            return cache["gen_len"] >= self.cfg.tconst.w_og
        return jnp.zeros((), bool)

    # -- dry-run specs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (assignment: weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        specs: Dict[str, Any] = {"tokens": f((B, L), jnp.int32)}
        if cfg.arch_type == "vlm":
            Tv = cfg.frontend_tokens
            specs["vision_embeds"] = f((B, Tv, cfg.frontend_dim),
                                       jnp.dtype(cfg.dtype))
            specs["vision_mask"] = f((B, L), jnp.bool_)
        if cfg.is_encdec:
            specs["audio_feats"] = f((B, cfg.encoder_seq, cfg.frontend_dim),
                                     jnp.dtype(cfg.dtype))
        return specs

    def cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        """ShapeDtypeStructs of the serve cache (eval_shape: no alloc)."""
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len))


def build_model(cfg: ModelConfig) -> ModelAPI:
    cfg.validate()
    return ModelAPI(cfg)

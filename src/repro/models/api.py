"""Unified model facade + the decode-side inference protocol.

Two surfaces live here:

* :class:`ModelAPI` — the training facade (init / forward / loss) plus
  thin compatibility wrappers for the legacy decode entry points
  (``init_cache`` / ``prefill`` / ``decode_step`` / ``resync``) used by
  the dry-run launcher and the complexity benchmarks.

* :class:`DecodeAPI` — the serving protocol.  A decode cache is a typed
  :class:`DecodeState` (registered pytree) with an explicit ``kv`` vs
  ``bookkeeping`` partition, so cache-size reporting (paper Fig 8g)
  reads the partition instead of guessing from field names.  The
  *physical* representation of ``kv`` is a pluggable
  :mod:`repro.models.layouts` backend (dense / paged / int8 /
  paged_int8) riding in the pytree aux data; the decode kernels consume
  it LAYOUT-NATIVELY through ``DecodeState.decode_views()`` — per-field
  KVViews carrying the physical buffers + page-table/scale metadata —
  so a paged step walks pages in-kernel and an int8 step fuses the
  dequant, with zero dense densification on the hot path
  (``DecodeState.merged`` survives as the test/parity oracle).  The
  protocol is slot-oriented for continuous batching:

    ``init_state(slots, max_len)``          fixed-shape multi-slot state
    ``prefill_into_slot(params, state, slot, tokens)``
                                            admit one request mid-flight
    ``step(params, state, token)``          one batched token, with the
                                            W_og resync fused on-device
    ``sync_mask(state)``                    per-slot (B,) boundary mask
    ``sync_rows(params, state, rows)``      COMPACTED row-wise resync:
                                            gather only the masked rows,
                                            run their O(N) sync at batch
                                            size 1, scatter back — non-
                                            boundary rows are never
                                            computed (amortized O(1)
                                            under staggered batching)

  ``maybe_sync`` is now *derived* (``sync_rows`` over ``sync_mask`` —
  zero pending rows means zero work), replacing PR-1's monolithic
  compute-all-rows-then-select cond.  :func:`decode_chunk` scans
  ``step`` so a k-token decode chunk runs as ONE dispatch with zero
  per-token host syncs, freezing slots whose on-device ``done`` flag
  was set by EOS.  Implementations exist for the TConst core, the dense
  LM family, and the encoder-decoder.

Every entry point takes/returns plain pytrees so the launchers can jit
them with explicit shardings.  ``input_specs`` produces the
ShapeDtypeStruct stand-ins used by the multi-pod dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.core import tconst as TC
from repro.layers.common import put_rows, take_rows, where_rows
from repro.models import encdec as ED
from repro.models import layouts as LT
from repro.models import lm as LM
from repro.sharding import rules as SH


def _is_tconst(cfg: ModelConfig) -> bool:
    return cfg.attention_mode in ("tconst", "tlin") and \
        cfg.arch_type not in ("ssm", "audio")


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE.  logits (B, L, V) f32; targets (B, L) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# DecodeState: the typed decode cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class DecodeState:
    """Decode-side cache with an explicit kv / bookkeeping partition and a
    pluggable physical layout.

    ``kv`` holds the true KV (and recurrent-state) buffers in the
    PHYSICAL representation chosen by ``layout`` — dense arrays, paged
    pools, or int8 + scales; ``kv_bytes`` (the paper Fig 8g quantity)
    therefore reflects the actual layout.  ``bookkeeping`` holds token-id
    buffers, lengths, per-slot phase counters and the EOS ``done`` mask
    (NOT KV cache), plus layout-owned fields (``layout__*`` prefix, e.g.
    the paged page table) which are hidden from the dense view.
    ``axes`` (static aux data) maps every DENSE field to its batch
    ("slot") axis; ``layout`` (static aux data) translates dense <->
    physical and implements layout-aware slot surgery.  ``mesh`` (static
    aux data, optional) is a :class:`repro.sharding.rules.MeshContext`:
    when set, every slot-surgery path re-pins its outputs to the
    per-field decode shardings (``with_sharding_constraint`` under jit,
    ``device_put`` eagerly), so the SAME code path runs single-device
    (mesh=None: all constraints vanish) and mesh-sharded.
    """

    kv: Dict[str, jax.Array]
    bookkeeping: Dict[str, jax.Array]
    axes: Dict[str, int]
    layout: Any = dataclasses.field(default_factory=LT.DenseLayout)
    mesh: Optional[SH.MeshContext] = None

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten_with_keys(self):
        children = (
            (jax.tree_util.GetAttrKey("kv"), self.kv),
            (jax.tree_util.GetAttrKey("bookkeeping"), self.bookkeeping),
        )
        return children, (tuple(sorted(self.axes.items())), self.layout,
                          self.mesh)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kv, bookkeeping = children
        axes, layout, mesh = aux
        return cls(kv, bookkeeping, dict(axes), layout, mesh)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_dense(cls, cache: Dict[str, Any], kv_keys: Tuple[str, ...],
                   axes: Dict[str, int], layout: Any = None,
                   layout_bk: Optional[Dict[str, Any]] = None
                   ) -> "DecodeState":
        """Wrap a dense logical cache dict, packing kv into ``layout``'s
        physical representation.  ``layout_bk`` carries layout-owned
        bookkeeping (e.g. a live page table) across re-wraps; omitted,
        the layout initialises it fresh."""
        layout = LT.DenseLayout() if layout is None else layout
        dense_kv = {k: v for k, v in cache.items() if k in kv_keys}
        bk = {k: v for k, v in cache.items() if k not in kv_keys}
        if layout_bk is None:
            name = next(iter(sorted(bk)))
            slots = bk[name].shape[axes[name]]
            layout_bk = layout.init_bookkeeping(slots)
        bk.update(layout_bk)
        all_axes = {**{k: axes[k] for k in cache}, **layout.bookkeeping_axes()}
        return cls(layout.pack(dense_kv, bk, all_axes), bk, all_axes, layout)

    def layout_bookkeeping(self) -> Dict[str, Any]:
        return {k: v for k, v in self.bookkeeping.items()
                if k.startswith(LT.LAYOUT_BK_PREFIX)}

    # -- mesh placement -----------------------------------------------------
    def _field_meta(self, name: str, in_kv: bool
                    ) -> Tuple[Optional[int], Optional[int]]:
        """(batch_axis, pool_axis) of one physical field — the inputs
        :func:`repro.sharding.rules.decode_field_spec` needs."""
        if name.startswith(LT.LAYOUT_BK_PREFIX):
            return None, None
        if in_kv:
            if isinstance(self.layout, LT.PagedLayout):
                pool_ax = self.layout.page_axis(name)
                if pool_ax is not None:
                    return None, pool_ax
            return self.layout._axis(name, self.axes), None
        return self.axes.get(name), None

    def field_shardings(self, ctx: SH.MeshContext) -> "DecodeState":
        """Same-structure DecodeState whose leaves are the per-field
        NamedShardings of ``ctx`` — usable directly as a jit
        in/out_shardings pytree.  Works on arrays and on eval_shape
        structs."""
        B = self.slots
        kv = {n: ctx.sharding(n, l.shape, batch=B,
                              baxis=self._field_meta(n, True)[0],
                              pool_axis=self._field_meta(n, True)[1])
              for n, l in self.kv.items()}
        bk = {n: ctx.sharding(n, l.shape, batch=B,
                              baxis=self._field_meta(n, False)[0])
              for n, l in self.bookkeeping.items()}
        return DecodeState(kv, bk, self.axes, self.layout, ctx)

    def _pinned(self, kv: Dict[str, Any], bk: Dict[str, Any]
                ) -> "DecodeState":
        """Build the successor state, re-pinning every field to the
        decode shardings when a mesh is attached (constraint under
        tracing, device_put eagerly).  mesh=None is the identity — the
        single-device path pays nothing."""
        out = DecodeState(kv, bk, self.axes, self.layout, self.mesh)
        ctx = self.mesh
        if ctx is None:
            return out
        sh = out.field_shardings(ctx)
        return DecodeState(
            {n: ctx.apply(v, sh.kv[n]) for n, v in kv.items()},
            {n: ctx.apply(v, sh.bookkeeping[n]) for n, v in bk.items()},
            self.axes, self.layout, ctx)

    def with_mesh(self, mesh) -> "DecodeState":
        """Attach a mesh context (None | Mesh | MeshContext) and place /
        constrain every field onto its decode sharding."""
        ctx = SH.as_mesh_context(mesh)
        if ctx is None:
            if self.mesh is None:
                return self
            return DecodeState(self.kv, self.bookkeeping, self.axes,
                               self.layout)
        staged = DecodeState(self.kv, self.bookkeeping, self.axes,
                             self.layout, ctx)
        return staged._pinned(self.kv, self.bookkeeping)

    # -- KVView: what the decode kernels consume ----------------------------
    def kv_views(self) -> Dict[str, Any]:
        """Per-field :mod:`repro.models.layouts` FieldViews over the
        PHYSICAL kv buffers (+ index/scale metadata) — the decode-kernel
        contract.  Views alias the buffers; no copy, no densification."""
        return self.layout.view(self.kv, self.bookkeeping, self.axes)

    def decode_views(self) -> Dict[str, Any]:
        """The dict the view-native decode kernels take: non-layout
        bookkeeping as plain arrays + kv fields as FieldViews."""
        bk = {k: v for k, v in self.bookkeeping.items()
              if not k.startswith(LT.LAYOUT_BK_PREFIX)}
        return {**bk, **self.kv_views()}

    def absorb(self, views: Dict[str, Any]) -> "DecodeState":
        """Rebuild a DecodeState from an updated ``decode_views`` dict.
        Views alias the physical buffers, so this is pure unwrapping —
        the inverse round-trip of ``merged``/``from_dense`` without the
        pack/unpack compute."""
        kv = LT.absorb_views({k: v for k, v in views.items()
                              if isinstance(v, LT.FieldView)})
        bk = {k: v for k, v in views.items()
              if not isinstance(v, LT.FieldView)}
        bk.update(self.layout_bookkeeping())
        return self._pinned(kv, bk)

    def merged(self) -> Dict[str, Any]:
        """The dense LOGICAL cache dict (layout-owned bookkeeping
        filtered out, kv unpacked/densified).  OFF the decode hot path:
        this is the test/parity ORACLE and the legacy-wrapper surface —
        the kernels themselves consume :meth:`kv_views`."""
        bk = {k: v for k, v in self.bookkeeping.items()
              if not k.startswith(LT.LAYOUT_BK_PREFIX)}
        return {**bk, **self.layout.unpack(self.kv, self.bookkeeping,
                                           self.axes)}

    def dense_shapes(self) -> Dict[str, jax.ShapeDtypeStruct]:
        """Shapes/dtypes of the dense logical kv view, without computing
        the unpack (works on concrete arrays and under tracing)."""
        return jax.eval_shape(
            lambda kv, bk: self.layout.unpack(kv, bk, self.axes),
            self.kv, self.bookkeeping)

    def with_bookkeeping(self, **updates: Any) -> "DecodeState":
        bk = dict(self.bookkeeping)
        bk.update(updates)
        return self._pinned(self.kv, bk)

    # -- accounting ---------------------------------------------------------
    def kv_bytes(self) -> int:
        """KV-cache footprint of the PHYSICAL representation (works on
        real arrays and on ShapeDtypeStructs from ``jax.eval_shape``), so
        paged pools and int8+scales report their true bytes."""
        return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(self.kv))

    def step_view_bytes(self) -> int:
        """HBM bytes a layout-native decode step actually touches —
        assigned pages + table for paged fields, physical buffers
        otherwise.  Host-side (reads the live page table); concrete
        arrays only.  Compare against :meth:`dense_logical_bytes`."""
        return LT.view_touched_bytes(self.kv_views())

    def assigned_kv_bytes(self) -> int:
        """KV bytes the live page tables actually reference: paged
        fields count their unique assigned pages — a prefix-SHARED page
        (mapped by several slots) is stored and counted ONCE — while
        non-paged fields report their physical buffers.  This is the
        prefix-sharing headline: physical cache scaling with *distinct*
        context rather than slot count.  Host-side; concrete arrays.

        GLOBAL-bytes guarantee: sharded jax Arrays report their global
        ``shape``/``nbytes``, so this (and :meth:`kv_bytes`,
        ``spill_cost``, the telemetry occupancy) is the whole-fleet
        number under a mesh, identical to the 1-device run — the
        per-device split is :meth:`per_device_kv_bytes`."""
        return LT.assigned_kv_bytes(self.kv_views())

    def per_device_kv_bytes(self) -> int:
        """Largest per-device share of the PHYSICAL kv buffers: for each
        addressable device, sum the bytes of its local shards, and
        report the max (replicated fields count fully on every device).
        Equals :meth:`kv_bytes` unmeshed; ≈ global / model_shards for
        the head-sharded decode layout.  Host-side; concrete arrays."""
        per: Dict[Any, int] = {}
        for leaf in jax.tree_util.tree_leaves(self.kv):
            shards = getattr(leaf, "addressable_shards", None)
            if shards is None:           # eval_shape struct: global bytes
                return self.kv_bytes()
            for s in shards:
                per[s.device] = per.get(s.device, 0) + s.data.nbytes
        return max(per.values()) if per else 0

    def dense_logical_bytes(self) -> int:
        """Bytes of the dense LOGICAL kv view — what a ``merged()``-based
        step would materialise and read per token (the pre-KVView cost
        model, kept as the benchmark's comparison baseline)."""
        return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
                   for s in self.dense_shapes().values())

    @property
    def slots(self) -> int:
        name, leaf = next(iter(sorted(self.bookkeeping.items())))
        return leaf.shape[self.axes[name]]

    # -- slot surgery -------------------------------------------------------
    def with_slot(self, slot: jax.Array, row: "DecodeState",
                  page_write_mask: Optional[jax.Array] = None,
                  exclude: Tuple[str, ...] = ()) -> "DecodeState":
        """Scatter a single-row state (batch size 1, dense layout) into
        slot ``slot``.  Bookkeeping is a per-field row write; kv goes
        through the layout (paged: page-map surgery touching only the
        slot's own pages).  ``page_write_mask`` (pages_per_slot,) bool
        restricts the paged write to the UNSHARED tail of the slot's
        page table — the copy-on-write admission contract: a page whose
        content is already resident (prefix sharing, refcount > 1) is
        mapped, never rewritten.  ``exclude`` skips kv fields by base
        name — the chunked prefill streams its length-axis KV in via
        :meth:`write_span` and finalises with everything else."""
        bk = dict(self.bookkeeping)
        for name, src in row.bookkeeping.items():
            if name.startswith(LT.LAYOUT_BK_PREFIX):
                continue
            bk[name] = jax.lax.dynamic_update_slice_in_dim(
                self.bookkeeping[name], src.astype(bk[name].dtype), slot,
                axis=self.axes[name])
        dense_row = row.layout.unpack(row.kv, row.bookkeeping, row.axes)
        kv = self.layout.write_slot(self.kv, self.bookkeeping, slot,
                                    dense_row, self.axes,
                                    page_mask=page_write_mask,
                                    exclude=exclude)
        return self._pinned(kv, bk)

    def read_slot(self, slot: jax.Array) -> Dict[str, Any]:
        """Dense logical kv row (batch size 1) of slot ``slot``, read
        through the layout (paged: gathered via the slot's OWN page-table
        row — adopted prefix-shared pages included; int8: dequantized).
        The KV-conditioned chunked prefill seeds its row cache from this
        so tail chunks attend the resident KV.  Admission path only."""
        return self.layout.read_slot(self.kv, self.bookkeeping, self.axes,
                                     slot)

    def write_span(self, slot: jax.Array, fields: Dict[str, Any],
                   length_axes: Dict[str, int], start: jax.Array,
                   min_page: Optional[jax.Array] = None) -> "DecodeState":
        """Chunk-granular slot write: scatter one prefill chunk's
        positions ``[start, start + C)`` of the given length-axis fields
        (dense logical, batch 1) into the slot through the layout —
        paged layouts write exactly the covered pages of the slot's
        table (entries below ``min_page`` — adopted shared pages — are
        redirected to TRASH), quantizing layouts quantize on write."""
        kv = self.layout.write_span(self.kv, self.bookkeeping, slot, fields,
                                    length_axes, self.axes, start,
                                    min_page=min_page)
        return self._pinned(kv, self.bookkeeping)

    def where_rows(self, rows: jax.Array, other: "DecodeState"
                   ) -> "DecodeState":
        """Per-slot select: take self where ``rows`` (B,) is True, else
        ``other``.  Used to freeze inactive/done slots inside a decode
        chunk."""
        bk = {name: where_rows(rows, leaf, other.bookkeeping[name],
                               self.axes[name])
              for name, leaf in self.bookkeeping.items()}
        kv = self.layout.where_rows(rows, self.kv, other.kv,
                                    self.bookkeeping, self.axes)
        return self._pinned(kv, bk)

    # -- slot snapshot / restore (session tiering) --------------------------
    def snapshot_slot(self, slot: jax.Array) -> Dict[str, Dict[str, Any]]:
        """Everything slot ``slot`` owns, in the PHYSICAL representation:
        ``{"bookkeeping": <non-layout rows, batch dim 1>, "kv": <layout
        snapshot>}``.  Dense/int8 kv snapshots are batch-axis row slices
        (int8 stays ``__q``/``__scale`` — compressed on host); paged kv
        snapshots gather exactly the slot's page-table row out of the
        pools.  Layout-owned bookkeeping (the page table itself) is NOT
        captured — a restore binds the snapshot to the destination
        slot's own fresh pages.  Jittable; the scheduler's spill path
        jits it once and ``device_get``s the result."""
        bk = {name: jax.lax.dynamic_slice_in_dim(leaf, slot, 1,
                                                 self.axes[name])
              for name, leaf in self.bookkeeping.items()
              if not name.startswith(LT.LAYOUT_BK_PREFIX)}
        return {"bookkeeping": bk,
                "kv": self.layout.snapshot_slot(self.kv, self.bookkeeping,
                                                self.axes, slot)}

    def restore_slot(self, slot: jax.Array,
                     snap: Dict[str, Dict[str, Any]]) -> "DecodeState":
        """Inverse of :meth:`snapshot_slot` — one jittable scatter of the
        snapshot into slot ``slot`` (ANY slot: the snapshot carries no
        slot identity).  Bit-exact: the snapshot is in the physical
        representation, so nothing is re-quantized or re-paged on the
        way back in.  Paged layouts scatter through the destination
        slot's CURRENT page-table row, which the caller must have
        pointed at exclusively-owned pages first."""
        bk = dict(self.bookkeeping)
        for name, src in snap["bookkeeping"].items():
            bk[name] = jax.lax.dynamic_update_slice_in_dim(
                self.bookkeeping[name], src.astype(bk[name].dtype), slot,
                axis=self.axes[name])
        kv = self.layout.restore_slot(self.kv, self.bookkeeping, self.axes,
                                      slot, snap["kv"])
        return self._pinned(kv, bk)


# ---------------------------------------------------------------------------
# Sampling + chunked decode (zero per-token host syncs)
# ---------------------------------------------------------------------------


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Per-slot sampling.  logits (B, V); temperature (B,) with <= 0
    meaning greedy.  Pure device code — safe inside a scanned step.

    ``key`` may be a single key — one categorical draw over the whole
    batch, so a slot's sample depends on which other slots share the
    batch — or a PER-SLOT key array (B, 2), where each row is sampled
    with its own key and the draw is independent of batch composition
    (the scheduler uses this for replay-identical session streams)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature, 1e-6)
    scaled = logits / t[:, None]
    if key.ndim == 2:
        sampled = jax.vmap(
            lambda k, row: jax.random.categorical(k, row, axis=-1)
        )(key, scaled).astype(jnp.int32)
    else:
        sampled = jax.random.categorical(
            key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def decode_chunk(decode: "DecodeAPI", params: Any, state: DecodeState,
                 token: jax.Array, key: jax.Array, temperature: jax.Array,
                 active: jax.Array, n_steps: int,
                 eos: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, DecodeState, jax.Array]:
    """Run ``n_steps`` decode steps as ONE ``lax.scan`` — a single
    dispatch, zero per-token host round-trips.  The W_og resync fires
    inside the scanned step via the compacted row-wise ``sync_rows``
    (see ``DecodeAPI.step``), correct per-slot even when slots sit at
    different phases.

    token: (B,) the token each slot feeds at the first step (its last
    sampled token).  active: (B,) bool; inactive slots are frozen
    bit-identically and keep echoing their input token.  eos: optional
    (B,) int32 end-of-sequence id per slot (< 0 disables); a slot that
    samples its EOS sets the on-device ``done`` flag in
    ``state.bookkeeping`` and is frozen for the rest of the chunk — the
    scheduler evicts it at the chunk boundary.  Returns (sampled tokens
    (B, n_steps), state, key).

    key: a single PRNG key (engine path: one split per step, shared
    batch draw) or PER-SLOT keys (B, 2) (scheduler path): each live row
    splits its own key per step and frozen rows do NOT advance, so a
    session's key-chain position is exactly its generated-token count —
    invariant to slot placement, batch composition and spill/resume.
    """
    per_slot = key.ndim == 2

    def body(carry, _):
        state, tok, key = carry
        done = state.bookkeeping["done"]
        live = jnp.logical_and(active, jnp.logical_not(done))
        logits, new_state = decode.step(params, state, tok)
        if per_slot:
            pair = jax.vmap(jax.random.split)(key)       # (B, 2, 2)
            nxt_key, sub = pair[:, 0], pair[:, 1]
            key = jnp.where(live[:, None], nxt_key, key)
        else:
            key, sub = jax.random.split(key)
        nxt = sample_tokens(logits, temperature, sub)
        nxt = jnp.where(live, nxt, tok)
        new_state = new_state.where_rows(live, state)
        if eos is not None:
            hit = jnp.logical_and(live,
                                  jnp.logical_and(eos >= 0, nxt == eos))
            new_state = new_state.with_bookkeeping(
                done=jnp.logical_or(done, hit))
        return (new_state, nxt, key), nxt

    (state, _, key), toks = jax.lax.scan(
        body, (state, token, key), None, length=n_steps)
    toks = jnp.moveaxis(toks, 0, 1) if n_steps else \
        jnp.zeros((token.shape[0], 0), jnp.int32)
    return toks, state, key


def speculative_acceptance(feed: jax.Array, samples: jax.Array,
                           budget: jax.Array, live: jax.Array,
                           eos: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """The pure acceptance rule of the speculative state machine
    (property-tested in isolation in tests/test_property.py).

    feed (B, C): the verified inputs — last sampled token, then the
    draft.  samples (B, C): the verify-exact samples, ``samples[:, c]``
    drawn from position c's logits with the c-th key of the slot's
    chain.  A draft token is accepted iff it EQUALS the sample the
    sequential decode would have emitted there; the committed count is

        m = min(longest matching draft prefix + 1, budget)

    — the ``+ 1`` is the bonus token sampled from the verify logits at
    the first mismatch (or after a fully-accepted draft), which is why
    ``m >= 1`` for every live row and the loop always progresses.
    ``budget`` (B,) caps acceptance at a family's window boundary
    (samples at positions ``>= budget`` may be garbage — they can only
    inflate the match count, never survive the cap, so they never reach
    a stream).  ``eos`` (B,, < 0 disables) truncates acceptance at the
    first emitted EOS inclusive.  Returns (m (B,) int32 — 0 for
    non-live rows — and hit (B,) bool: EOS inside the accepted
    prefix)."""
    C = feed.shape[1]
    match = (feed[:, 1:] == samples[:, :C - 1]).astype(jnp.int32)
    a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    m = jnp.minimum(a + 1, jnp.maximum(budget, 1))
    if eos is not None:
        is_eos = jnp.logical_and(eos[:, None] >= 0,
                                 samples == eos[:, None])
        first = jnp.argmax(is_eos, axis=1).astype(jnp.int32)
        has = jnp.any(is_eos, axis=1)
        m = jnp.where(has, jnp.minimum(m, first + 1), m)
        hit = jnp.logical_and(has, first < m)
    else:
        hit = jnp.zeros_like(live)
    m = jnp.where(live, m, 0).astype(jnp.int32)
    return m, jnp.logical_and(hit, live)


def spec_chunk(decode: "DecodeAPI", params: Any, state: DecodeState,
               token: jax.Array, draft: jax.Array, key: jax.Array,
               temperature: jax.Array, active: jax.Array,
               eos: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array, jax.Array, DecodeState,
                          jax.Array]:
    """One speculative round as ONE dispatch: verify a k-token draft
    per slot against the resident KV (:meth:`DecodeAPI.verify_chunk`),
    accept the longest verify-exact prefix + one bonus token, commit by
    a counter advance, roll back by NOT advancing.  The sampled-token
    contract of :func:`decode_chunk` is preserved EXACTLY: emitted
    tokens, per-slot key-chain positions, ``done`` flags and counters
    all match what ``n_steps=m`` sequential steps would have produced —
    speculation changes wall-clock only, never a stream.

    token (B,): each slot's last sampled token.  draft (B, k): proposed
    continuations.  key: per-slot (B, 2) keys (scheduler path — exact
    for any temperature) or ONE shared key (engine path — each verify
    position would need the shared key's batch-composition-dependent
    draw, so only greedy decoding is exact there; the Engine enforces
    that).  Returns (toks (B, k+1) — positions ``>= m`` are garbage,
    frozen rows echo ``token`` — m (B,) accepted counts, last (B,) the
    new last-sampled token, state, key)."""
    B, k_draft = draft.shape
    C = k_draft + 1
    per_slot = key.ndim == 2
    done0 = state.bookkeeping["done"]
    live = jnp.logical_and(active, jnp.logical_not(done0))
    synced = decode.maybe_sync(params, state)
    feed = jnp.concatenate([token[:, None], draft.astype(jnp.int32)],
                           axis=1)
    logits, verified = decode.verify_chunk(params, synced, feed)

    # the slot's key chain, C steps ahead of time: keys_seq[c] is the
    # chain AFTER c emitted tokens, subs[c] the c-th sampling key —
    # exactly decode_chunk's per-step split sequence
    keys_seq, subs = [key], []
    for _ in range(C):
        if per_slot:
            pair = jax.vmap(jax.random.split)(keys_seq[-1])
            keys_seq.append(pair[:, 0])
            subs.append(pair[:, 1])
        else:
            nxt, sub = jax.random.split(keys_seq[-1])
            keys_seq.append(nxt)
            subs.append(sub)
    s = jnp.stack([sample_tokens(logits[:, c], temperature, subs[c])
                   for c in range(C)], axis=1)               # (B, C)

    m, hit = speculative_acceptance(feed, s, decode.verify_budget(synced),
                                    live, eos)
    new_state = decode.advance_lengths(verified, m)
    new_state = new_state.with_bookkeeping(
        done=jnp.logical_or(done0, hit))
    new_state = new_state.where_rows(live, state)

    if per_slot:
        # each live row's chain advances by exactly its m — invariant to
        # slot placement and batch composition, like decode_chunk
        stack = jnp.stack(keys_seq, axis=0)                  # (C+1, B, 2)
        key = jnp.take_along_axis(stack, m[None, :, None], axis=0)[0]
    else:
        key = keys_seq[-1]
    last = jnp.take_along_axis(s, jnp.maximum(m - 1, 0)[:, None],
                               axis=1)[:, 0]
    last = jnp.where(live, last, token)
    toks = jnp.where(live[:, None], s, token[:, None])
    return toks, m, last, new_state, key


# ---------------------------------------------------------------------------
# DecodeAPI protocol + per-family implementations
# ---------------------------------------------------------------------------


class DecodeAPI:
    """Slot-oriented decode protocol (see module docstring).

    All methods are pure jax functions of their array arguments, so the
    serving layer can jit them (``step`` composes into
    :func:`decode_chunk`'s scan).  The sync surface is row-wise:
    ``sync_mask`` names the boundary rows, ``sync_rows`` syncs exactly
    those rows (compacted — non-masked rows are never computed), and
    ``maybe_sync`` is derived from the two.  ``raw_step`` is the
    un-fused cache-hit step used by the instrumented engine path that
    times hits and misses separately (Fig 8).
    """

    cfg: ModelConfig

    # required surface ------------------------------------------------------
    def init_state(self, slots: int, max_len: int) -> DecodeState:
        raise NotImplementedError

    def prefill(self, params, batch: Dict[str, Any], max_len: int
                ) -> Tuple[jax.Array, DecodeState]:
        """Full-batch prefill (all slots, same-length prompts)."""
        raise NotImplementedError

    def prefill_into_slot(self, params, state: DecodeState, slot: jax.Array,
                          tokens: jax.Array,
                          extras: Optional[Dict[str, Any]] = None,
                          page_write_mask: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, DecodeState]:
        """Admit one request: prefill prompt ``tokens`` (L,) and scatter
        the resulting row into ``slot``.  Returns (logits (V,), state).

        ``page_write_mask`` (pages_per_slot,) bool is the TAIL-ONLY
        prefill entry for prefix sharing: table entries where the mask
        is False (pages adopted from the prefix map, content already
        resident) are excluded from the paged scatter, so admission
        writes only the unshared tail of the prompt."""
        raise NotImplementedError

    def raw_step(self, params, state: DecodeState, token: jax.Array
                 ) -> Tuple[jax.Array, DecodeState]:
        """One cache-hit decode step, NO sync check (instrumentation)."""
        raise NotImplementedError

    # sync surface (identity for models without periodic resync) ------------
    def sync_mask(self, state: DecodeState) -> jax.Array:
        """(B,) bool: rows whose next step must be preceded by the O(N)
        synchronisation."""
        return jnp.zeros((state.slots,), bool)

    def sync_rows(self, params, state: DecodeState, rows: jax.Array
                  ) -> DecodeState:
        """Sync exactly the rows where ``rows`` is True; all other rows
        come through bit-identical AND uncomputed."""
        return state

    def maybe_sync(self, params, state: DecodeState) -> DecodeState:
        """Derived fused sync: ``sync_rows`` over ``sync_mask``.  Zero
        masked rows means zero sync work — this is the on-device
        decision, no host round-trip."""
        return self.sync_rows(params, state, self.sync_mask(state))

    # chunked KV-conditioned prefill (admission path) ------------------------
    def supports_chunked_prefill(self, extras: Optional[Dict[str, Any]]
                                 = None) -> bool:
        """True when this family (with these per-request extras) can run
        admission through :meth:`prefill_into_slot_chunked`."""
        return False

    def _chunk_resident_start(self, resident_len: int) -> int:
        """Where the chunk loop may start given a resident shared
        prefix.  KV-only families resume after the adopted pages
        (tail-only compute); families carrying RECURRENT state (ssm /
        conv — a function of the full prompt, not reconstructible from
        the adopted KV) must forward from position 0 — adopted pages
        still save the writes (``min_page``) and the bytes, just not
        the tail compute."""
        return resident_len

    def chunked_prefill_fits(self, prompt_len: int, resident_len: int,
                             chunk: int, max_len: int) -> bool:
        """True when the chunk grid over this prompt stays inside the
        ``max_len`` buffers.  The last chunk's padding spills up to
        ``chunk - 1`` positions past the prompt (harmless: overwritten
        by decode appends, masked meanwhile) — but it must not spill
        past ``max_len``, where ``dynamic_update_slice`` would CLAMP the
        write onto earlier, real positions.  The scheduler falls back to
        one-shot admission for the rare prompt this excludes."""
        start0 = min(self._chunk_resident_start(resident_len),
                     (prompt_len - 1) // chunk * chunk)
        n_chunks = -(-(prompt_len - start0) // chunk)
        return start0 + n_chunks * chunk <= max_len

    def prefill_into_slot_chunked(self, params, state: DecodeState,
                                  slot: jax.Array, tokens: jax.Array,
                                  extras: Optional[Dict[str, Any]] = None,
                                  page_write_mask: Optional[jax.Array]
                                  = None, resident_len: int = 0,
                                  chunk: int = 32
                                  ) -> Tuple[jax.Array, DecodeState,
                                             Dict[str, int]]:
        """Chunked, KV-conditioned admission: process the prompt in
        fixed-size chunks of ``chunk`` tokens, each chunk attending
        against the KV already resident for this slot — earlier chunks
        AND, when ``resident_len > 0``, the prefix-shared pages the
        scheduler adopted into the slot's page table — so forward
        compute scales with the *unshared tail* rather than the full
        prompt, and every dispatch has a fixed shape (one compile per
        chunk shape instead of one per prompt length).

        Host-side driver: loops jitted fixed-shape steps (seed → gather
        resident → per-chunk forward + chunk-granular ``write_span`` →
        finalize).  ``resident_len`` must be page-aligned (it is
        ``adopted_pages * page_size`` by construction); when it covers
        the whole prompt, the driver still forwards the final chunk for
        the admission logits but redirects its page writes to TRASH
        (``min_page``) so adopted pages are never written.  Returns
        ``(logits (V,), state, info)`` with ``info['forward_tokens']``
        the number of prompt positions actually forwarded (padded to the
        chunk grid) — the tail-only accounting asserted in tests and
        recorded in ``BENCH_inference.json``.

        Streams are token-identical to the one-shot ``prefill_into_slot``
        admission (float-associativity noise only; int8 layouts within
        the documented quantization tolerance).
        """
        assert self.supports_chunked_prefill(extras), \
            "this family/extras combination requires one-shot admission"
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        L = int(tokens.shape[0])
        assert L >= 1, "cannot admit an empty prompt"
        chunk = int(chunk)
        fns = _chunked_jits(self)
        max_len = self._state_max_len(state)
        # >= one chunk must be forwarded for the admission logits even
        # when the page-aligned resident prefix covers the whole prompt
        start0 = int(min(self._chunk_resident_start(resident_len),
                         (L - 1) // chunk * chunk))
        n_chunks = -(-(L - start0) // chunk)
        buf = np.zeros((n_chunks * chunk,), np.int32)
        buf[:L - start0] = tokens[start0:]
        row = fns["seed"](params, extras, max_len)
        min_page = None
        if resident_len > 0 and isinstance(state.layout, LT.PagedLayout):
            # adopted (refcount > 1) pages are never written, even when
            # the chunk loop recomputes their positions
            min_page = np.int32(resident_len // state.layout.page)
        if start0 > 0:
            # chunks resume mid-prompt: seed the row cache's resident
            # prefix from the slot's adopted pages so they can attend it
            row = fns["gather"](state, slot, row, np.int32(resident_len))
        logits = None
        n_valid = np.int32(L)
        for j in range(n_chunks):
            start = np.int32(start0 + j * chunk)
            ctoks = jnp.asarray(buf[j * chunk:(j + 1) * chunk])[None]
            logits, row, chunk_kv = fns["chunk"](params, row, ctoks, start,
                                                 n_valid)
            if chunk_kv:
                state = fns["span"](state, slot, chunk_kv, start, min_page)
        last_start = start0 + (n_chunks - 1) * chunk
        out = logits[0, (L - 1) - last_start]
        state = fns["finalize"](state, slot, row, np.int32(L))
        return out, state, {"forward_tokens": n_chunks * chunk,
                            "chunks": n_chunks}

    # chunked-prefill hooks (families using the generic driver implement
    # these; TConst overrides the driver itself with the bucketed path) -----
    def _state_max_len(self, state: DecodeState) -> int:
        raise NotImplementedError

    def _chunk_seed_row(self, params, extras, max_len: int
                        ) -> Dict[str, Any]:
        """Fresh dense row cache (batch 1) before any chunk runs."""
        raise NotImplementedError

    def _chunk_fn(self, params, row: Dict[str, Any], tokens: jax.Array,
                  start: jax.Array, n_valid: jax.Array):
        """One fixed-shape chunk forward (``n_valid`` = total prompt
        length, so recurrent-state families can exclude the last chunk's
        padding): returns (logits (1, C, V), updated row, chunk_kv — the
        chunk's length-axis KV)."""
        raise NotImplementedError

    def _chunk_gather_resident(self, state: DecodeState, slot: jax.Array,
                               row: Dict[str, Any], resident_len: jax.Array
                               ) -> Dict[str, Any]:
        """Seed the row cache's positions [0, resident_len) from the
        slot's resident KV (adopted prefix-shared pages included, read
        through the layout) so tail chunks attend it."""
        dense = state.read_slot(slot)
        out = dict(row)
        for f, la in self._LENGTH_AXES.items():
            if f not in row:
                continue
            S = row[f].shape[la]
            keep = (jnp.arange(S) < resident_len).reshape(
                (1,) * la + (S,) + (1,) * (row[f].ndim - la - 1))
            out[f] = jnp.where(keep, dense[f].astype(row[f].dtype), row[f])
        return out

    def _chunk_span_write(self, state: DecodeState, slot: jax.Array,
                          chunk_kv: Dict[str, Any], start: jax.Array,
                          min_page) -> DecodeState:
        return state.write_span(slot, chunk_kv, self._LENGTH_AXES, start,
                                min_page=min_page)

    def _chunk_finalize(self, state: DecodeState, slot: jax.Array,
                        row: Dict[str, Any], n_valid: jax.Array
                        ) -> DecodeState:
        """Write the row's bookkeeping + non-length kv (recurrent state,
        cross KV); the length-axis KV was already streamed in by the
        per-chunk ``write_span`` calls."""
        row = dict(row)
        row["len"] = jnp.full((1,), n_valid, jnp.int32)
        row["done"] = jnp.zeros((1,), bool)
        return state.with_slot(slot, self._row_state(row),
                               exclude=tuple(self._LENGTH_AXES))

    # prefix-sharing surface (host-side hooks for the scheduler) ------------
    def stable_prefix_len(self, prompt_len: int) -> int:
        """Longest prompt prefix whose paged KV is fully written at
        admission AND a pure function of the prompt token ids — only
        pages wholly inside it may enter the prefix-sharing map.  Models
        with a growing positional KV write every prompt position at
        prefill, so the whole prompt is stable."""
        return prompt_len

    def sync_anticipated(self, state: DecodeState, n_steps: int
                         ) -> np.ndarray:
        """Host-side (B,) bool: slots whose periodic O(N) sync MAY fire
        within the next ``n_steps`` decode steps (conservative over-
        approximation is fine — an early copy-on-write fork loses some
        sharing, never correctness).  Models without a periodic sync
        never rewrite resident pages, so nothing is anticipated."""
        return np.zeros((state.slots,), bool)

    # admission caching (session tiering) ------------------------------------
    def admission_key(self, tokens: np.ndarray,
                      extras: Optional[Dict[str, Any]] = None
                      ) -> Optional[bytes]:
        """Content digest under which this request's POST-ADMISSION slot
        state may be stored and re-used, or None when admission is not a
        pure function of (params, prompt ids) — the default.  Families
        whose admission recomputes state that depends only on the prompt
        (the tconst/tlin O(N) resync) return a digest, so a scheduler
        with a tier store turns re-admission of a known prompt into an
        O(1) restore with zero forward compute."""
        return None

    # fused step ------------------------------------------------------------
    def step(self, params, state: DecodeState, token: jax.Array
             ) -> Tuple[jax.Array, DecodeState]:
        """maybe_sync + raw_step: the unit scanned by decode_chunk."""
        return self.raw_step(params, self.maybe_sync(params, state), token)

    # speculative decoding surface (see serving/speculative.py) -------------
    def supports_speculative(self) -> bool:
        """True when this family can verify a drafted chunk and roll
        back by a length-counter decrement alone.  Families carrying
        recurrent state (ssm / conv) cannot: the state after C steps is
        not a function of a truncation point."""
        return False

    def verify_chunk(self, params, state: DecodeState, feed: jax.Array
                     ) -> Tuple[jax.Array, DecodeState]:
        """Score C fed tokens per slot against the resident KV in ONE
        fixed-shape dispatch.  feed (B, C): position c is the token the
        sequential decode would feed at generation offset c (the slot's
        last sampled token, then the draft).  All C keys/values are
        written through the views at the sequential write sites;
        counters are NOT advanced — acceptance of an m-prefix is
        :meth:`advance_lengths` and the rejected suffix becomes stale
        garbage beyond the counter, causally masked and overwritten by
        the next round before it could be attended.  Returns (logits
        (B, C, V), state)."""
        raise NotImplementedError

    def verify_budget(self, state: DecodeState) -> jax.Array:
        """(B,) int32: how many verified tokens each slot may ACCEPT
        this round without overrunning a fixed-size window.  Evaluated
        on the post-sync state; families without a bounded generation
        window are unconstrained."""
        return jnp.full((state.slots,), jnp.int32(2 ** 30))

    def advance_lengths(self, state: DecodeState, m: jax.Array
                        ) -> DecodeState:
        """Commit an accepted m-token prefix (B,) by advancing the
        per-slot length counter — the ONLY state change acceptance
        makes (rollback is the complement: simply not advancing)."""
        return state.with_bookkeeping(len=state.bookkeeping["len"] + m)

    # shared layout wiring (subclasses set the _KV_KEYS / _AXES /
    # _LENGTH_AXES / _QUANT_FIELDS class attributes) -------------------------
    _KV_KEYS: Tuple[str, ...] = ()
    _AXES: Dict[str, int] = {}
    _LENGTH_AXES: Dict[str, int] = {}
    _QUANT_FIELDS: Tuple[str, ...] = ()
    mesh: Optional[SH.MeshContext] = None

    def _mesh_scope(self):
        """Trace-time decode-mesh scope: the per-family step/sync/chunk
        bodies trace inside it, so the kernel dispatch in
        :mod:`repro.kernels.ops` sees the mesh and shard_map-wraps the
        decode / prefill-chunk attention.  mesh=None is a no-op."""
        from repro.kernels import ops
        return ops.decode_mesh_scope(self.mesh)

    def _bind(self, slots: int, max_len: int):
        return LT.bind_layout(self.layout, slots=slots, max_len=max_len,
                              length_axes=self._LENGTH_AXES,
                              quant_fields=self._QUANT_FIELDS,
                              dtype=self.cfg.dtype)

    def _wrap_new(self, cache: Dict[str, Any], max_len: int) -> DecodeState:
        layout = self._bind(cache["done"].shape[0], max_len)
        return DecodeState.from_dense(cache, self._KV_KEYS, self._AXES,
                                      layout).with_mesh(self.mesh)

    def _rewrap(self, state: DecodeState, cache: Dict[str, Any]
                ) -> DecodeState:
        return DecodeState.from_dense(
            cache, self._KV_KEYS, self._AXES, state.layout,
            layout_bk=state.layout_bookkeeping()).with_mesh(self.mesh)

    def _row_state(self, cache: Dict[str, Any]) -> DecodeState:
        """Wrap a batch-1 prefilled row (always dense — the batched
        state's layout does the slot scatter)."""
        return DecodeState.from_dense(cache, self._KV_KEYS, self._AXES)

    def _check_prefill_layout(self, cache: Dict[str, Any], max_len: int
                              ) -> None:
        """Full-batch prefill can't place rows in an under-sized paged
        pool — but only when the cache actually has paged fields."""
        layout = self._bind(cache["done"].shape[0], max_len)
        if isinstance(layout, LT.PagedLayout) and not layout.preallocated \
                and any(f in cache for f, _ in layout.fields):
            raise ValueError(
                "full-batch prefill cannot place rows in an under-sized "
                "paged pool (pool_pages < slots * pages_per_slot); use "
                "the scheduler's page allocator via prefill_into_slot, "
                "or leave pool_pages=None")


# Per-decode jitted chunked-prefill steps.  Keyed by the (frozen,
# value-hashable) DecodeAPI instance, so every scheduler/engine sharing an
# equal config+layout reuses ONE set of compiled chunk shapes — the
# bucketing that collapses prefill compiles from one-per-prompt-length to
# one-per-(chunk-shape x masked-variant).
_CHUNK_JITS: Dict[Any, Dict[str, Any]] = {}


def _mesh_scoped(decode: "DecodeAPI", fn):
    """Run ``fn``'s trace inside the decode-mesh scope (see
    ``DecodeAPI._mesh_scope``); identity when the decode has no mesh."""
    if decode.mesh is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with decode._mesh_scope():
            return fn(*args, **kwargs)
    return wrapped


def _chunked_jits(decode: "DecodeAPI") -> Dict[str, Any]:
    # the fns are chunk-size-agnostic (the size arrives via call-time
    # shapes), so normalise prefill_chunk out of the key: an Engine and
    # a scheduler that differ only in the default knob share one set
    # (the key keeps the mesh: a sharded decode compiles its own set)
    key = dataclasses.replace(decode, prefill_chunk=None)
    fns = _CHUNK_JITS.get(key)
    if fns is None:
        if hasattr(key, "_chunk_bucketed"):
            fns = {"bucketed": jax.jit(_mesh_scoped(key,
                                                    key._chunk_bucketed))}
        else:
            fns = {
                "seed": jax.jit(key._chunk_seed_row,
                                static_argnums=(2,)),
                "gather": jax.jit(key._chunk_gather_resident),
                "chunk": jax.jit(_mesh_scoped(key, key._chunk_fn)),
                "span": jax.jit(key._chunk_span_write),
                "finalize": jax.jit(key._chunk_finalize),
            }
        _CHUNK_JITS[key] = fns
    return fns


@dataclasses.dataclass(frozen=True)
class TConstDecode(DecodeAPI):
    """Paper §4 serving: O(1) cache-hit steps, periodic O(N) resync.

    Layout-native: ``raw_step`` hands the kernels ``state.decode_views()``
    — the physical buffers plus index/scale metadata — so the hit step
    never densifies the cache (mode="tlin" keeps the O(N) history KV per
    block, which the paged layouts attend via the in-kernel page-table
    walk).  The resync decision lives ON DEVICE: ``sync_mask`` reads only
    the per-slot ``gen_len`` phase counters, and ``sync_rows`` gathers
    ALL boundary rows' bookkeeping in one dispatch (bucketed — see
    ``tconst.resync_rows_compacted``), reruns their O(N) synchronisation
    at the compacted batch size, and writes the fresh ctx/hist KV back
    THROUGH the layout (paged: page-map surgery on the rows' own pages;
    int8: fresh values quantized on write).  ``resync`` rebuilds that KV
    from the raw token ids, so the sync path reads no KV at all — slots
    admitted at different times stay token-for-token identical to their
    solo runs without paying for each other's misses.
    """

    cfg: ModelConfig
    layout: LT.LayoutSpec = LT.DENSE_SPEC
    prefill_chunk: Optional[int] = None
    mesh: Optional[SH.MeshContext] = None

    _KV_KEYS = TC.KV_KEYS
    _AXES = TC.CACHE_BATCH_AXES
    _LENGTH_AXES = TC.LENGTH_AXES
    _QUANT_FIELDS = TC.QUANT_FIELDS

    @property
    def mode(self) -> str:
        return self.cfg.attention_mode

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap_new(
            TC.init_tconst_cache(self.cfg, slots, max_len, self.mode),
            max_len)

    def prefill(self, params, batch, max_len):
        logits, cache = TC.prefill(params, batch["tokens"], self.cfg,
                                   max_len, mode=self.mode)
        self._check_prefill_layout(cache, max_len)
        return logits, self._wrap_new(cache, max_len)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None,
                          page_write_mask=None):
        max_len = state.bookkeeping["tokens"].shape[1]
        logits, row = TC.prefill(params, tokens[None], self.cfg, max_len,
                                 mode=self.mode)
        return logits[0], state.with_slot(slot, self._row_state(row),
                                          page_write_mask=page_write_mask)

    # chunked admission: the TConst prefill is resync (already a fixed
    # max_len-shaped dispatch) + a generation-window pass, so "chunking"
    # here means BUCKETING — the whole admission becomes one fixed-shape
    # dispatch (prompt padded into the token buffer, window pass padded
    # to W_og with validity masks): ONE compile for every prompt length.
    # Tail-only compute does NOT apply: the paper's resync rebuilds the
    # compressed ctx KV from the full history by construction (content-
    # addressed ctx-KV reuse is the ROADMAP open item).
    def supports_chunked_prefill(self, extras=None):
        return True

    def chunked_prefill_fits(self, prompt_len, resident_len, chunk,
                             max_len):
        return True          # one max_len-shaped dispatch: always fits

    def prefill_into_slot_chunked(self, params, state, slot, tokens,
                                  extras=None, page_write_mask=None,
                                  resident_len=0, chunk=32):
        del extras, resident_len, chunk       # see class comment above
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        L = int(tokens.shape[0])
        max_len = state.bookkeeping["tokens"].shape[1]
        buf = np.zeros((1, max_len), np.int32)
        buf[0, :L] = tokens
        logits, state = _chunked_jits(self)["bucketed"](
            params, state, slot, jnp.asarray(buf),
            jnp.full((1,), L, jnp.int32), page_write_mask)
        return logits, state, {"forward_tokens": max_len, "chunks": 1}

    def _chunk_bucketed(self, params, state, slot, buf, n_valid, mask):
        logits, row = TC.prefill_bucketed(params, buf, n_valid, self.cfg,
                                          mode=self.mode)
        return logits[0], state.with_slot(slot, self._row_state(row),
                                          page_write_mask=mask)

    def stable_prefix_len(self, prompt_len: int) -> int:
        """The trailing 1..W_og prompt tokens live in the dense gen
        window, not the paged history KV, until the first resync — only
        the hist_len prefix is resident in pages at admission."""
        g0 = ((prompt_len - 1) % self.cfg.tconst.w_og) + 1
        return prompt_len - g0

    def admission_key(self, tokens, extras=None):
        """TConst admission is resync + a generation-window pass, both
        pure functions of the prompt ids (``TC.RESYNC_INPUT_KEYS``) —
        the ctx/hist KV carries no sampling or wall-clock state — so the
        admitted slot is content-addressable by prompt digest and a
        shared-history re-admission becomes an O(1) restore instead of
        the O(N) resync (the ROADMAP's content-addressed ctx-KV
        reuse)."""
        if extras:
            return None
        return TC.admission_digest(np.asarray(tokens), self.mode,
                                   self.cfg.tconst.w_og)

    def sync_anticipated(self, state, n_steps):
        """A slot resyncs when gen_len reaches W_og; gen_len grows by at
        most one per decode step, so gen_len + n_steps >= W_og bounds
        every resync the next chunk can fire (EOS-frozen slots are
        excluded — they are evicted at the boundary, never synced)."""
        gen = np.asarray(state.bookkeeping["gen_len"])
        done = np.asarray(state.bookkeeping["done"])
        return np.logical_and(gen + n_steps >= self.cfg.tconst.w_og,
                              np.logical_not(done))

    def raw_step(self, params, state, token):
        with self._mesh_scope():
            logits, out = TC.decode_step_views(params, state.decode_views(),
                                               token, self.cfg,
                                               mode=self.mode)
        return logits, state.absorb(out)

    # speculative surface: verify writes into the O(1) gen window; a
    # slot may only ACCEPT up to the window boundary (the resync that
    # follows rebuilds ctx/hist KV from token ids, so accepted tokens
    # recorded in the id buffer survive it; rejected ones beyond
    # gen_len were never recorded)
    def supports_speculative(self):
        return True

    def verify_chunk(self, params, state, feed):
        with self._mesh_scope():
            logits, out = TC.verify_chunk_views(params,
                                                state.decode_views(),
                                                feed, self.cfg,
                                                mode=self.mode)
        return logits, state.absorb(out)

    def verify_budget(self, state):
        return jnp.maximum(
            jnp.int32(self.cfg.tconst.w_og) -
            state.bookkeeping["gen_len"], 0).astype(jnp.int32)

    def advance_lengths(self, state, m):
        return state.with_bookkeeping(
            gen_len=state.bookkeeping["gen_len"] + m)

    def sync_mask(self, state):
        return TC.pending_resync_rows(state.bookkeeping, self.cfg)

    def sync_rows(self, params, state, rows):
        """Layout-aware batched compacted resync (see class docstring):
        ONE gather of the pending rows' bookkeeping, ONE O(N) resync at
        the bucketed pending count, KV written back through the layout.
        Zero pending rows selects the identity branch — this is the
        on-device decision, no host round-trip."""
        cfg = self.cfg
        axes = TC.CACHE_BATCH_AXES

        def factory(kb: int):
            def branch(state, idx, sel):
                bk = state.bookkeeping
                row_in = {f: take_rows(bk[f], idx, axes[f])
                          for f in TC.RESYNC_INPUT_KEYS}
                new = TC.resync(params, row_in, cfg, self.mode)
                out_bk = dict(bk)
                views = state.kv_views()
                for f, v in new.items():
                    if f in views:
                        views[f] = views[f].scatter_rows(idx, sel, v)
                    else:
                        old = take_rows(bk[f], idx, axes[f])
                        vals = where_rows(sel, v.astype(bk[f].dtype), old,
                                          axes[f])
                        out_bk[f] = put_rows(bk[f], idx, vals, axes[f])
                return state._pinned(LT.absorb_views(views), out_bk)
            return branch

        return TC.compacted_rows_switch(rows, state, factory)


@dataclasses.dataclass(frozen=True)
class DenseDecode(DecodeAPI):
    """Decoder-only LM family (dense / moe / ssm / hybrid / vlm): a
    conventional growing KV cache (or O(1) recurrent state for ssm),
    no periodic sync.  The max_len-axis K/V buffers support the paged
    and int8 layouts."""

    cfg: ModelConfig
    layout: LT.LayoutSpec = LT.DENSE_SPEC
    prefill_chunk: Optional[int] = None
    mesh: Optional[SH.MeshContext] = None

    _KV_KEYS = LM.KV_KEYS
    _AXES = LM.CACHE_BATCH_AXES
    _LENGTH_AXES = LM.LENGTH_AXES
    _QUANT_FIELDS = LM.QUANT_FIELDS

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap_new(LM.init_kv_cache(self.cfg, slots, max_len),
                              max_len)

    def _max_len(self, state: DecodeState, fallback: int) -> int:
        shapes = state.dense_shapes()
        for key in ("k", "dense_k"):
            if key in shapes:
                return shapes[key].shape[2]
        return fallback                      # pure ssm: no positional buffer

    def prefill(self, params, batch, max_len):
        logits, cache = LM.lm_prefill(
            params, batch["tokens"], self.cfg, max_len,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))
        self._check_prefill_layout(cache, max_len)
        return logits, self._wrap_new(cache, max_len)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None,
                          page_write_mask=None):
        extras = extras or {}
        max_len = self._max_len(state, tokens.shape[0])
        logits, cache = LM.lm_prefill(
            params, tokens[None], self.cfg, max_len,
            vision_embeds=None if "vision_embeds" not in extras else
            extras["vision_embeds"][None],
            vision_mask=None if "vision_mask" not in extras else
            extras["vision_mask"][None])
        return logits[0], state.with_slot(slot, self._row_state(cache),
                                          page_write_mask=page_write_mask)

    def raw_step(self, params, state, token):
        with self._mesh_scope():
            logits, out = LM.lm_decode_step_views(params,
                                                  state.decode_views(),
                                                  token, self.cfg)
        return logits, state.absorb(out)

    def supports_speculative(self):
        # recurrent ssm/conv state advances through VERIFIED-BUT-REJECTED
        # tokens and cannot be rolled back by a length decrement
        return self.cfg.arch_type != "ssm" and not self.cfg.hybrid_parallel

    def verify_chunk(self, params, state, feed):
        with self._mesh_scope():
            logits, out = LM.lm_verify_chunk_views(params,
                                                   state.decode_views(),
                                                   feed, self.cfg)
        return logits, state.absorb(out)

    # chunked admission hooks (generic driver in DecodeAPI) -----------------
    def supports_chunked_prefill(self, extras=None):
        # VLM vision positions depend on a prompt-length-shaped mask (one
        # compile per length regardless) — those admissions stay one-shot
        return not (extras and "vision_embeds" in extras)

    def _chunk_resident_start(self, resident_len):
        # ssm/conv recurrent state is a function of the FULL prompt and
        # cannot be reconstructed from adopted KV pages: recurrent
        # families forward from 0 (adopted pages still save writes/bytes)
        if self.cfg.arch_type == "ssm" or self.cfg.hybrid_parallel:
            return 0
        return resident_len

    def _state_max_len(self, state):
        return self._max_len(state, 0)

    def _chunk_seed_row(self, params, extras, max_len):
        del params, extras
        return LM.init_kv_cache(self.cfg, 1, max_len)

    def _chunk_fn(self, params, row, tokens, start, n_valid):
        return LM.lm_prefill_chunk(params, row, tokens, start, n_valid,
                                   self.cfg)


@dataclasses.dataclass(frozen=True)
class EncDecDecode(DecodeAPI):
    """Encoder-decoder: per-session encoder memory is pre-projected into
    the per-layer cross K/V cache at admission."""

    cfg: ModelConfig
    layout: LT.LayoutSpec = LT.DENSE_SPEC
    prefill_chunk: Optional[int] = None
    mesh: Optional[SH.MeshContext] = None

    _KV_KEYS = ED.KV_KEYS
    _AXES = ED.CACHE_BATCH_AXES
    _LENGTH_AXES = ED.LENGTH_AXES
    _QUANT_FIELDS = ED.QUANT_FIELDS

    def init_state(self, slots: int, max_len: int) -> DecodeState:
        return self._wrap_new(ED.init_encdec_cache(self.cfg, slots, max_len),
                              max_len)

    def prefill(self, params, batch, max_len):
        logits, cache = ED.encdec_prefill(params, batch["tokens"],
                                          batch["audio_feats"], self.cfg,
                                          max_len)
        self._check_prefill_layout(cache, max_len)
        return logits, self._wrap_new(cache, max_len)

    def prefill_into_slot(self, params, state, slot, tokens, extras=None,
                          page_write_mask=None):
        if not extras or "audio_feats" not in extras:
            raise ValueError(
                "encoder-decoder sessions need extras={'audio_feats': "
                "(T_enc, frontend_dim)} at submission")
        max_len = state.dense_shapes()["k"].shape[2]
        logits, cache = ED.encdec_prefill(
            params, tokens[None], extras["audio_feats"][None], self.cfg,
            max_len)
        return logits[0], state.with_slot(slot, self._row_state(cache),
                                          page_write_mask=page_write_mask)

    def raw_step(self, params, state, token):
        with self._mesh_scope():
            logits, out = ED.encdec_decode_step_views(params,
                                                      state.decode_views(),
                                                      token, self.cfg)
        return logits, state.absorb(out)

    def supports_speculative(self):
        return True

    def verify_chunk(self, params, state, feed):
        with self._mesh_scope():
            logits, out = ED.encdec_verify_chunk_views(
                params, state.decode_views(), feed, self.cfg)
        return logits, state.absorb(out)

    # chunked admission hooks: the encoder runs ONCE at seed time (fixed
    # encoder_seq shape — one compile), pre-projecting the cross K/V the
    # decoder chunks then attend; only the growing self-attention KV is
    # chunk-written.
    def supports_chunked_prefill(self, extras=None):
        return True

    def _state_max_len(self, state):
        return state.dense_shapes()["k"].shape[2]

    def _chunk_seed_row(self, params, extras, max_len):
        if not extras or "audio_feats" not in extras:
            raise ValueError(
                "encoder-decoder sessions need extras={'audio_feats': "
                "(T_enc, frontend_dim)} at submission")
        return ED.encdec_seed_cache(params, extras["audio_feats"][None],
                                    self.cfg, max_len)

    def _chunk_fn(self, params, row, tokens, start, n_valid):
        return ED.encdec_prefill_chunk(params, row, tokens, start, n_valid,
                                       self.cfg)


def build_decode(cfg: ModelConfig, layout: Any = None,
                 prefill_chunk: Optional[int] = None,
                 mesh: Any = None) -> DecodeAPI:
    """Build the decode protocol for ``cfg`` with a cache layout chosen
    by ``layout`` ("dense" | "paged" | "int8" | "paged_int8" |
    LayoutSpec | None).  ``prefill_chunk`` is the default chunk size for
    chunked KV-conditioned admission (None = one-shot full-prompt
    prefill); the scheduler reads it unless given its own.  ``mesh``
    (None | jax Mesh | MeshContext) makes the decode mesh-native:
    ``init_state`` places its output with ``jax.device_put`` onto the
    per-field decode shardings (see
    :func:`repro.sharding.rules.decode_shardings`), every state-surgery
    path re-pins its results, and the decode / prefill-chunk attention
    runs shard_map-sharded over the model axis."""
    spec = LT.as_spec(layout)
    if prefill_chunk is not None and prefill_chunk < 1:
        raise ValueError("prefill_chunk must be positive (or None for "
                         "one-shot admission)")
    ctx = SH.as_mesh_context(mesh)
    if ctx is not None and cfg.n_kv_heads > 1 and \
            cfg.n_kv_heads % ctx.model_shards != 0:
        # MQA (n_kv_heads == 1) is exempt: its KV replicates over model
        # (nothing to split); a >1 indivisible head count is a
        # mis-sized mesh
        raise ValueError(
            f"model axis ({ctx.model_shards}) must divide the KV heads "
            f"({cfg.n_kv_heads}) for head-sharded decode")
    if _is_tconst(cfg):
        return TConstDecode(cfg, spec, prefill_chunk, ctx)
    if cfg.is_encdec:
        return EncDecDecode(cfg, spec, prefill_chunk, ctx)
    return DenseDecode(cfg, spec, prefill_chunk, ctx)


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelAPI:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def init(self, key: jax.Array):
        cfg = self.cfg
        if _is_tconst(cfg):
            return TC.init_tconst_lm(key, cfg)
        if cfg.is_encdec:
            return ED.init_encdec(key, cfg)
        return LM.init_lm(key, cfg)

    # -- training -----------------------------------------------------------
    def forward(self, params, batch: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        tokens = batch["tokens"]
        if _is_tconst(cfg):
            return TC.tconst_forward(params, tokens, cfg,
                                     mode=cfg.attention_mode)
        if cfg.is_encdec:
            return ED.encdec_forward(params, tokens, batch["audio_feats"],
                                     cfg)
        return LM.lm_forward(
            params, tokens, cfg,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"))

    def loss(self, params, batch: Dict[str, Any]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(params, batch)
        tokens = batch["tokens"]
        ce = cross_entropy(logits[:, :-1], tokens[:, 1:])
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving (compat wrappers over DecodeAPI; cache is a DecodeState) ---
    @property
    def decode(self) -> DecodeAPI:
        return build_decode(self.cfg)

    def init_cache(self, batch: int, max_len: int) -> DecodeState:
        return self.decode.init_state(batch, max_len)

    def prefill(self, params, batch: Dict[str, Any], max_len: int
                ) -> Tuple[jax.Array, DecodeState]:
        return self.decode.prefill(params, batch, max_len)

    def decode_step(self, params, state: DecodeState, token: jax.Array
                    ) -> Tuple[jax.Array, DecodeState]:
        return self.decode.raw_step(params, state, token)

    def resync(self, params, state: DecodeState) -> DecodeState:
        """TConst periodic global synchronisation — full, all-rows
        (the legacy schedule where every row shares one phase)."""
        cfg = self.cfg
        if _is_tconst(cfg):
            cache = TC.resync(params, state.merged(), cfg,
                              mode=cfg.attention_mode)
            return DecodeState.from_dense(
                cache, TC.KV_KEYS, TC.CACHE_BATCH_AXES, state.layout,
                layout_bk=state.layout_bookkeeping())
        return state

    def needs_resync(self, state: DecodeState) -> jax.Array:
        if _is_tconst(self.cfg):
            return self.decode.sync_mask(state)
        return jnp.zeros((), bool)

    # -- dry-run specs -----------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this shape
        (assignment: weak-type-correct, shardable, no device allocation)."""
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        f = jax.ShapeDtypeStruct
        specs: Dict[str, Any] = {"tokens": f((B, L), jnp.int32)}
        if cfg.arch_type == "vlm":
            Tv = cfg.frontend_tokens
            specs["vision_embeds"] = f((B, Tv, cfg.frontend_dim),
                                       jnp.dtype(cfg.dtype))
            specs["vision_mask"] = f((B, L), jnp.bool_)
        if cfg.is_encdec:
            specs["audio_feats"] = f((B, cfg.encoder_seq, cfg.frontend_dim),
                                     jnp.dtype(cfg.dtype))
        return specs

    def cache_specs(self, batch: int, max_len: int) -> DecodeState:
        """ShapeDtypeStructs of the serve cache (eval_shape: no alloc)."""
        return jax.eval_shape(
            lambda: self.init_cache(batch, max_len))


def build_model(cfg: ModelConfig) -> ModelAPI:
    cfg.validate()
    return ModelAPI(cfg)

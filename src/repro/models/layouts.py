"""Pluggable physical cache layouts behind :class:`repro.models.api.DecodeState`.

The decode kernels (``core/tconst.py``, ``models/lm.py``, ``models/encdec.py``)
consume a *logical* dense cache — a dict of fixed-shape arrays with a batch
("slot") axis.  A :class:`CacheLayout` decides how those arrays are
*physically* stored inside ``DecodeState.kv`` and translates between the two:

* :class:`DenseLayout`    — physical == logical (PR-1 behaviour).
* :class:`PagedLayout`    — every length-axis KV buffer is split into
  fixed-size pages living in one shared pool per field, with a per-slot
  page table in bookkeeping.  The pool can be sized *below*
  ``slots * pages_per_slot`` (short sessions stop paying ``max_len``
  bytes); page assignment is host-side slot surgery in the scheduler —
  admission/eviction touch the page map, never full rows.  Token ids and
  phase counters are bookkeeping and stay dense.
* :class:`QuantizedLayout` — int8 KV with per-vector (last-axis) float32
  scales, dequantized on the fly when the decode kernels read the state.
  Symmetric round-to-nearest; requantizing an unchanged entry is
  idempotent, so no drift accumulates across decode steps.

All layouts are frozen (hashable) dataclasses: they ride in the
``DecodeState`` pytree **aux data**, so jitted functions specialise on the
layout exactly like they specialise on shapes.

Layout methods take the *dense field axes* map (the model's
``CACHE_BATCH_AXES``) and derive physical axes themselves; layout-owned
bookkeeping fields carry the ``layout__`` prefix so the model-facing dense
view (``DecodeState.merged``) can filter them out.

Note on fidelity: paged unpack gathers pages into the dense logical view
before the kernels run (and pack scatters back), so paging here buys the
*memory footprint* and the admission/eviction surgery of a paged server,
not in-kernel page-table walks — a production port would fuse the gather
into the attention kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.common import where_rows

LAYOUT_BK_PREFIX = "layout__"
PAGE_TABLE = LAYOUT_BK_PREFIX + "page_table"


# ---------------------------------------------------------------------------
# Spec (user-facing knob) and binding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """User-facing layout choice, before shapes are known.

    kind: "dense" | "paged" | "int8".
    page_size: tokens per page (paged).
    pool_pages: total pages in the shared pool (paged); None = full
    ``slots * pages_per_slot`` (no saving, but no allocator needed —
    required for the uniform-batch ``prefill`` path).  A smaller pool
    needs the scheduler's page allocator.
    """

    kind: str = "dense"
    page_size: int = 64
    pool_pages: Optional[int] = None

    def __post_init__(self):
        if self.kind not in ("dense", "paged", "int8"):
            raise ValueError(f"unknown cache layout kind: {self.kind!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be positive")
        if self.pool_pages is not None and self.pool_pages < 1:
            raise ValueError("pool_pages must be positive (or None for "
                             "the full slots * pages_per_slot pool)")


DENSE_SPEC = LayoutSpec()


def as_spec(layout) -> LayoutSpec:
    if layout is None:
        return DENSE_SPEC
    if isinstance(layout, LayoutSpec):
        return layout
    if isinstance(layout, str):
        return LayoutSpec(kind=layout)
    raise TypeError(f"layout must be LayoutSpec | str | None, got {layout!r}")


def bind_layout(spec: LayoutSpec, *, slots: int, max_len: int,
                length_axes: Dict[str, int], quant_fields: Tuple[str, ...],
                dtype: str) -> "CacheLayout":
    """Turn a shape-free spec into a bound (hashable) layout instance."""
    spec = as_spec(spec)
    if spec.kind == "dense":
        return DenseLayout()
    if spec.kind == "int8":
        return QuantizedLayout(fields=tuple(sorted(quant_fields)),
                               dtype=dtype)
    pps = -(-max_len // spec.page_size)
    pool = slots * pps if spec.pool_pages is None else spec.pool_pages
    return PagedLayout(page=spec.page_size, pool_pages=pool, max_len=max_len,
                       slots=slots,
                       fields=tuple(sorted(length_axes.items())))


# ---------------------------------------------------------------------------
# Dense (base: generic pack-through + per-field slot surgery)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DenseLayout:
    """Physical == logical.  Also the base class providing the generic
    per-field slot surgery used by the other layouts' pass-through
    fields."""

    name = "dense"

    # -- logical <-> physical ----------------------------------------------
    def pack(self, dense: Dict[str, Any], bk: Dict[str, Any],
             axes: Dict[str, int]) -> Dict[str, Any]:
        return dict(dense)

    def unpack(self, kv: Dict[str, Any], bk: Dict[str, Any],
               axes: Dict[str, int]) -> Dict[str, Any]:
        return dict(kv)

    # -- layout-owned bookkeeping ------------------------------------------
    def init_bookkeeping(self, slots: int) -> Dict[str, Any]:
        return {}

    def bookkeeping_axes(self) -> Dict[str, int]:
        return {}

    # -- slot surgery on the PHYSICAL representation -----------------------
    def _axis(self, field: str, axes: Dict[str, int]) -> int:
        return axes[field]

    def where_rows(self, rows: jax.Array, new_kv: Dict[str, Any],
                   old_kv: Dict[str, Any], bk: Dict[str, Any],
                   axes: Dict[str, int]) -> Dict[str, Any]:
        return {f: where_rows(rows, new_kv[f], old_kv[f],
                              self._axis(f, axes)) for f in new_kv}

    def write_slot(self, kv: Dict[str, Any], bk: Dict[str, Any],
                   slot: jax.Array, dense_row: Dict[str, Any],
                   axes: Dict[str, int]) -> Dict[str, Any]:
        """Scatter a 1-slot dense row into physical slot ``slot``."""
        packed = self.pack(dense_row, bk, axes)
        out = {}
        for f, dst in kv.items():
            src = packed[f].astype(dst.dtype)
            out[f] = jax.lax.dynamic_update_slice_in_dim(
                dst, src, slot, axis=self._axis(f, axes))
        return out


# ---------------------------------------------------------------------------
# int8 with per-vector scales
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-vector (last axis) int8 quantization."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


@dataclasses.dataclass(frozen=True)
class QuantizedLayout(DenseLayout):
    """int8 KV + float32 per-vector scales (``f`` -> ``f__q``/``f__scale``).

    KV bytes shrink ~4x vs float32 (1 byte per element + 4/head_dim
    scale overhead); decode kernels read the dequantized dense view, so
    accuracy is within the symmetric-int8 rounding error (~0.4% of each
    vector's max magnitude per element — the documented tolerance).
    """

    fields: Tuple[str, ...] = ()
    dtype: str = "float32"
    name = "int8"

    def pack(self, dense, bk, axes):
        out = {}
        for f, v in dense.items():
            if f in self.fields:
                out[f + "__q"], out[f + "__scale"] = quantize_int8(v)
            else:
                out[f] = v
        return out

    def unpack(self, kv, bk, axes):
        out = {}
        for f, v in kv.items():
            if f.endswith("__q"):
                base = f[:-3]
                out[base] = dequantize_int8(v, kv[base + "__scale"],
                                            jnp.dtype(self.dtype))
            elif not f.endswith("__scale"):
                out[f] = v
        return out

    def _axis(self, field, axes):
        for suffix in ("__q", "__scale"):
            if field.endswith(suffix):
                return axes[field[: -len(suffix)]]
        return axes[field]


# ---------------------------------------------------------------------------
# Paged
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLayout(DenseLayout):
    """Length-axis KV buffers as fixed-size pages in a shared pool.

    For every paged field the dense (..., B, max_len, ...) buffer becomes
    a physical (..., pool_pages + 1, page, ...) pool — the extra page is
    TRASH: unassigned page-table entries point at it, so packs of
    unallocated regions land there and unpacks of them read garbage that
    the kernels' validity masks never touch.  One int32 page table
    ``layout__page_table`` (slots, pages_per_slot) in bookkeeping is
    shared by all paged fields.

    Constraint (asserted): a paged field's batch axis must immediately
    precede its length axis, so page gather/scatter is a single take /
    indexed set.

    Fields absent from the cache (e.g. ``hist_k`` in pure-tconst mode)
    are skipped, making the layout a no-op for caches that are already
    O(1).
    """

    page: int = 64
    pool_pages: int = 0
    max_len: int = 0
    slots: int = 0
    fields: Tuple[Tuple[str, int], ...] = ()
    name = "paged"

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page)

    @property
    def trash(self) -> int:
        return self.pool_pages

    @property
    def preallocated(self) -> bool:
        """Full pool: identity page table works with no allocator."""
        return self.pool_pages >= self.slots * self.pages_per_slot

    def _length_axis(self, field: str) -> Optional[int]:
        for f, la in self.fields:
            if f == field:
                return la
        return None

    # -- bookkeeping --------------------------------------------------------
    def init_bookkeeping(self, slots):
        pps = self.pages_per_slot
        if self.preallocated:
            pt = jnp.arange(slots * pps, dtype=jnp.int32).reshape(slots, pps)
        else:
            pt = jnp.full((slots, pps), self.trash, jnp.int32)
        return {PAGE_TABLE: pt}

    def bookkeeping_axes(self):
        return {PAGE_TABLE: 0}

    # -- paging primitives --------------------------------------------------
    def _to_pages(self, x: jax.Array, la: int) -> jax.Array:
        """(..., B, L, rest) -> (..., B, pps, page, rest)."""
        pps = self.pages_per_slot
        pad = pps * self.page - x.shape[la]
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[la] = (0, pad)
            x = jnp.pad(x, widths)
        return x.reshape(x.shape[:la] + (pps, self.page) + x.shape[la + 1:])

    def pack(self, dense, bk, axes):
        pt = bk[PAGE_TABLE]
        out = {}
        for f, v in dense.items():
            la = self._length_axis(f)
            if la is None:
                out[f] = v
                continue
            assert axes[f] == la - 1, (f, axes[f], la)
            pages = self._to_pages(v, la)          # (..., B, pps, page, rest)
            pool_shape = (v.shape[:la - 1] + (self.pool_pages + 1, self.page)
                          + v.shape[la + 1:])
            idx = (slice(None),) * (la - 1) + (pt,)
            out[f] = jnp.zeros(pool_shape, v.dtype).at[idx].set(pages)
        return out

    def unpack(self, kv, bk, axes):
        pt = bk[PAGE_TABLE]
        out = {}
        for f, v in kv.items():
            la = self._length_axis(f)
            if la is None:
                out[f] = v
                continue
            gathered = jnp.take(v, pt, axis=la - 1)  # (..., B, pps, page, rest)
            merged = gathered.reshape(
                gathered.shape[:la] + (-1,) + gathered.shape[la + 2:])
            out[f] = jax.lax.slice_in_dim(merged, 0, self.max_len, axis=la)
        return out

    # -- slot surgery -------------------------------------------------------
    def where_rows(self, rows, new_kv, old_kv, bk, axes):
        pt = bk[PAGE_TABLE]
        # slot mask -> page mask over the pool (real pages are uniquely
        # owned; the trash page's pick is arbitrary and its content dead)
        page_rows = jnp.zeros((self.pool_pages + 1,), bool).at[pt].set(
            jnp.broadcast_to(rows[:, None], pt.shape))
        out = {}
        for f in new_kv:
            la = self._length_axis(f)
            if la is None:
                out[f] = where_rows(rows, new_kv[f], old_kv[f], axes[f])
            else:
                out[f] = where_rows(page_rows, new_kv[f], old_kv[f], la - 1)
        return out

    def write_slot(self, kv, bk, slot, dense_row, axes):
        """Page-map surgery: only the slot's own pages are touched."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)      # (pps,)
        out = {}
        for f, dst in kv.items():
            la = self._length_axis(f)
            src = dense_row[f].astype(dst.dtype)
            if la is None:
                out[f] = jax.lax.dynamic_update_slice_in_dim(
                    dst, src, slot, axis=axes[f])
                continue
            pages = self._to_pages(src, la)       # (..., 1, pps, page, rest)
            pages = jax.lax.index_in_dim(pages, 0, axis=la - 1,
                                         keepdims=False)
            idx = (slice(None),) * (la - 1) + (pt_row,)
            out[f] = dst.at[idx].set(pages)
        return out

"""Pluggable physical cache layouts behind :class:`repro.models.api.DecodeState`.

The decode kernels (``core/tconst.py``, ``models/lm.py``,
``models/encdec.py``) consume the cache through **KVViews** — per-field
descriptors (:class:`DenseView` / :class:`QuantView` / :class:`PagedView`)
produced by ``CacheLayout.view(kv, bookkeeping, axes)``.  A view holds the
PHYSICAL buffers plus the index/scale metadata needed to read or append
one token *in that representation*: the kernels walk the page table /
apply the per-vector scales themselves, and nothing on the decode hot
path materialises the dense ``slots x max_len`` logical cache.  The dense
logical dict (``DecodeState.merged`` via :meth:`pack`/:meth:`unpack`)
survives only as the test/parity oracle and for O(N) admission paths
(prefill, resync row scatter).

Layouts:

* :class:`DenseLayout`     — physical == logical.
* :class:`PagedLayout`     — every length-axis KV buffer is split into
  fixed-size pages living in one shared pool per field, with a per-slot
  page table in bookkeeping.  The pool can be sized *below*
  ``slots * pages_per_slot`` (short sessions stop paying ``max_len``
  bytes); page assignment is host-side slot surgery in the scheduler —
  admission/eviction touch the page map, never full rows.  With
  ``quant_fields`` set ("paged_int8") the pool pages hold int8 vectors
  and the per-vector float32 scales ride in a parallel scale pool — the
  page metadata — so footprint composes (~4x on top of the pool saving).
* :class:`QuantizedLayout` — int8 KV with per-vector (last-axis) float32
  scales.  Decode kernels fuse the dequantisation into the QK/AV loops
  (Pallas) or read the dequantised values per-field (XLA fallback).
  Symmetric round-to-nearest; requantizing an unchanged entry is
  idempotent, so no drift accumulates across decode steps.

All layouts are frozen (hashable) dataclasses: they ride in the
``DecodeState`` pytree **aux data**, so jitted functions specialise on the
layout exactly like they specialise on shapes.  Views are registered
pytrees, so they ride ``lax.fori_loop`` carries and ``lax.scan`` bodies.

Layout methods take the *dense field axes* map (the model's
``CACHE_BATCH_AXES``) and derive physical axes themselves; layout-owned
bookkeeping fields carry the ``layout__`` prefix so the model-facing dense
view (``DecodeState.merged``) can filter them out.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers.common import put_rows, take_rows, where_rows

LAYOUT_BK_PREFIX = "layout__"
PAGE_TABLE = LAYOUT_BK_PREFIX + "page_table"

_QUANT_SUFFIXES = ("__q", "__scale")


def _base_name(field: str) -> str:
    for suffix in _QUANT_SUFFIXES:
        if field.endswith(suffix):
            return field[: -len(suffix)]
    return field


# ---------------------------------------------------------------------------
# Spec (user-facing knob) and binding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """User-facing layout choice, before shapes are known.

    kind: "dense" | "paged" | "int8" | "paged_int8".
    page_size: tokens per page (paged / paged_int8).
    pool_pages: total pages in the shared pool (paged); None = full
    ``slots * pages_per_slot`` (no saving, but no allocator needed —
    required for the uniform-batch ``prefill`` path).  A smaller pool
    needs the scheduler's page allocator.
    """

    kind: str = "dense"
    page_size: int = 64
    pool_pages: Optional[int] = None

    def __post_init__(self):
        if self.kind not in ("dense", "paged", "int8", "paged_int8"):
            raise ValueError(f"unknown cache layout kind: {self.kind!r}")
        if self.page_size < 1:
            raise ValueError("page_size must be positive")
        if self.pool_pages is not None and self.pool_pages < 1:
            raise ValueError("pool_pages must be positive (or None for "
                             "the full slots * pages_per_slot pool)")


DENSE_SPEC = LayoutSpec()


def as_spec(layout) -> LayoutSpec:
    if layout is None:
        return DENSE_SPEC
    if isinstance(layout, LayoutSpec):
        return layout
    if isinstance(layout, str):
        return LayoutSpec(kind=layout)
    raise TypeError(f"layout must be LayoutSpec | str | None, got {layout!r}")


def bind_layout(spec: LayoutSpec, *, slots: int, max_len: int,
                length_axes: Dict[str, int], quant_fields: Tuple[str, ...],
                dtype: str) -> "CacheLayout":
    """Turn a shape-free spec into a bound (hashable) layout instance."""
    spec = as_spec(spec)
    if spec.kind == "dense":
        return DenseLayout()
    if spec.kind == "int8":
        return QuantizedLayout(fields=tuple(sorted(quant_fields)),
                               dtype=dtype)
    pps = -(-max_len // spec.page_size)
    pool = slots * pps if spec.pool_pages is None else spec.pool_pages
    quant = tuple(sorted(quant_fields)) if spec.kind == "paged_int8" else ()
    return PagedLayout(page=spec.page_size, pool_pages=pool, max_len=max_len,
                       slots=slots,
                       fields=tuple(sorted(length_axes.items())),
                       quant_fields=quant, dtype=dtype)


# ---------------------------------------------------------------------------
# int8 primitives
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-vector (last axis) int8 quantization."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# KVView: per-field physical descriptors the decode kernels consume
# ---------------------------------------------------------------------------


class FieldView:
    """Base class for per-field cache views (see module docstring).

    The per-layer convention: after peeling all leading layer axes with
    :meth:`layer`, the LOGICAL field is (B, S, KV, D) — batch axis 0,
    length axis 1 — and token writes/attends are defined.  ``dense()``
    works at any level and is the oracle escape hatch."""

    def layer(self, i) -> "FieldView":
        raise NotImplementedError

    def set_layer(self, i, sub: "FieldView") -> "FieldView":
        raise NotImplementedError

    def dense(self) -> jax.Array:
        raise NotImplementedError

    def write_token(self, pos: jax.Array, vec: jax.Array) -> "FieldView":
        """Append one (B, KV, D) vector at per-slot position ``pos`` (B,).
        Only valid at the per-layer level."""
        raise NotImplementedError

    def scatter_rows(self, idx: jax.Array, sel: jax.Array,
                     rows: jax.Array) -> "FieldView":
        """Write dense logical ``rows`` (k rows along the batch axis)
        into slots ``idx`` (k,), but only where ``sel`` (k,) is True —
        unselected slots come through bit-identical.  Stacked level."""
        raise NotImplementedError


def _put_selected(arr: jax.Array, idx: jax.Array, sel: jax.Array,
                  rows: jax.Array, axis: int) -> jax.Array:
    old = take_rows(arr, idx, axis)
    vals = where_rows(sel, rows.astype(arr.dtype), old, axis)
    return put_rows(arr, idx, vals, axis)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseView(FieldView):
    """Physical == logical: one dense array."""

    data: jax.Array
    batch_axis: int = 0

    def tree_flatten(self):
        return (self.data,), (self.batch_axis,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    def layer(self, i):
        return DenseView(jax.lax.dynamic_index_in_dim(
            self.data, i, 0, keepdims=False), max(0, self.batch_axis - 1))

    def set_layer(self, i, sub):
        return DenseView(jax.lax.dynamic_update_index_in_dim(
            self.data, sub.data.astype(self.data.dtype), i, 0),
            self.batch_axis)

    def dense(self):
        return self.data

    def write_token(self, pos, vec):
        b = jnp.arange(vec.shape[0])
        return DenseView(self.data.at[b, pos].set(
            vec.astype(self.data.dtype)), self.batch_axis)

    def scatter_rows(self, idx, sel, rows):
        return DenseView(_put_selected(self.data, idx, sel, rows,
                                       self.batch_axis), self.batch_axis)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantView(FieldView):
    """int8 values + per-vector (last axis) float32 scales."""

    q: jax.Array
    scale: jax.Array
    batch_axis: int = 0
    dtype: str = "float32"

    def tree_flatten(self):
        return (self.q, self.scale), (self.batch_axis, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def layer(self, i):
        return QuantView(
            jax.lax.dynamic_index_in_dim(self.q, i, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(self.scale, i, 0, keepdims=False),
            max(0, self.batch_axis - 1), self.dtype)

    def set_layer(self, i, sub):
        return QuantView(
            jax.lax.dynamic_update_index_in_dim(self.q, sub.q, i, 0),
            jax.lax.dynamic_update_index_in_dim(self.scale, sub.scale, i, 0),
            self.batch_axis, self.dtype)

    def dense(self):
        return dequantize_int8(self.q, self.scale, jnp.dtype(self.dtype))

    def write_token(self, pos, vec):
        b = jnp.arange(vec.shape[0])
        qv, sv = quantize_int8(vec)
        return QuantView(self.q.at[b, pos].set(qv),
                         self.scale.at[b, pos].set(sv),
                         self.batch_axis, self.dtype)

    def scatter_rows(self, idx, sel, rows):
        qr, sr = quantize_int8(rows)
        return QuantView(
            _put_selected(self.q, idx, sel, qr, self.batch_axis),
            _put_selected(self.scale, idx, sel, sr, self.batch_axis),
            self.batch_axis, self.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PagedView(FieldView):
    """Length-axis field as a shared page pool + per-slot page table.

    ``storage`` is the pool in its element representation — a
    :class:`DenseView` (float pool ``(..., pool+1, page, KV, D)``) or a
    :class:`QuantView` (int8 pool + float32 scale pool, the paged_int8
    composition).  ``lead`` counts the leading layer axes still stacked
    on the pool; the page table (B, pages_per_slot) is shared across
    them.  The decode kernels receive the pool + table directly
    (``repro.kernels.paged_decode_attention``)."""

    storage: FieldView
    page_table: jax.Array
    page: int = 0
    max_len: int = 0
    trash: int = 0
    lead: int = 0

    def tree_flatten(self):
        return (self.storage, self.page_table), \
            (self.page, self.max_len, self.trash, self.lead)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def pages_per_slot(self) -> int:
        return self.page_table.shape[-1]

    @property
    def quant(self) -> bool:
        return isinstance(self.storage, QuantView)

    def _pool_children(self):
        if self.quant:
            return (self.storage.q, self.storage.scale)
        return (self.storage.data,)

    def _rebuild(self, pools):
        if self.quant:
            st = QuantView(pools[0], pools[1], self.storage.batch_axis,
                           self.storage.dtype)
        else:
            st = DenseView(pools[0], self.storage.batch_axis)
        return PagedView(st, self.page_table, self.page, self.max_len,
                         self.trash, self.lead)

    def layer(self, i):
        v = self._rebuild(tuple(
            jax.lax.dynamic_index_in_dim(p, i, 0, keepdims=False)
            for p in self._pool_children()))
        return dataclasses.replace(v, lead=self.lead - 1)

    def set_layer(self, i, sub: "PagedView"):
        return self._rebuild(tuple(
            jax.lax.dynamic_update_index_in_dim(p, s, i, 0)
            for p, s in zip(self._pool_children(), sub._pool_children())))

    def dense(self):
        """Gather pages into the dense logical array — ORACLE/debug only
        (this is exactly the densification the kernels avoid)."""
        la = self.lead + 1
        out = []
        for p in self._pool_children():
            g = jnp.take(p, self.page_table, axis=self.lead)
            g = g.reshape(g.shape[:la] + (-1,) + g.shape[la + 2:])
            out.append(jax.lax.slice_in_dim(g, 0, self.max_len, axis=la))
        if self.quant:
            return dequantize_int8(out[0], out[1],
                                   jnp.dtype(self.storage.dtype))
        return out[0]

    def _to_pages(self, x: jax.Array, la: int) -> jax.Array:
        pps = self.pages_per_slot
        pad = pps * self.page - x.shape[la]
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[la] = (0, pad)
            x = jnp.pad(x, widths)
        return x.reshape(x.shape[:la] + (pps, self.page) + x.shape[la + 1:])

    def write_token(self, pos, vec):
        """Append through the page table: physical page ``pt[b, pos //
        page]``, offset ``pos % page`` — only the owning page is touched."""
        assert self.lead == 0, "write_token needs a per-layer view"
        b = jnp.arange(vec.shape[0])
        pidx = self.page_table[b, pos // self.page]
        off = pos % self.page
        if self.quant:
            qv, sv = quantize_int8(vec)
            return self._rebuild((
                self.storage.q.at[pidx, off].set(qv),
                self.storage.scale.at[pidx, off].set(sv)))
        return self._rebuild((self.storage.data.at[pidx, off].set(
            vec.astype(self.storage.data.dtype)),))

    def scatter_rows(self, idx, sel, rows):
        """Write k dense logical rows through the rows' own pages (page-
        map surgery: other slots' pages are never touched)."""
        la = self.lead + 1                      # length axis at this level
        pt_rows = jnp.take(self.page_table, idx, axis=0)     # (k, pps)
        parts = [rows]
        if self.quant:
            parts = list(quantize_int8(rows))
        pools = []
        for pool, vals in zip(self._pool_children(), parts):
            pages = self._to_pages(vals.astype(pool.dtype), la)
            old = jnp.take(pool, pt_rows, axis=self.lead)
            pages = where_rows(sel, pages, old, self.lead)
            ix = (slice(None),) * self.lead + (pt_rows,)
            pools.append(pool.at[ix].set(pages))
        return self._rebuild(tuple(pools))


def absorb_views(views: Dict[str, FieldView]) -> Dict[str, jax.Array]:
    """Inverse of ``CacheLayout.view``: unwrap updated views back into the
    physical ``DecodeState.kv`` dict.  Pure unwrapping — the views alias
    the physical buffers, so there is no repack compute."""
    kv: Dict[str, jax.Array] = {}
    for f, v in views.items():
        st = v.storage if isinstance(v, PagedView) else v
        if isinstance(st, QuantView):
            kv[f + "__q"], kv[f + "__scale"] = st.q, st.scale
        else:
            kv[f] = st.data
    return kv


def _paged_assigned_bytes(v: "PagedView") -> int:
    """Bytes of the UNIQUE assigned pages of one paged field (+ scale
    pages).  ``np.unique`` over the table means a page mapped by several
    slots (prefix sharing) is counted ONCE — the physical truth."""
    pt = np.asarray(v.page_table)
    assigned = int(np.sum(np.unique(pt) != v.trash))
    total = 0
    for pool in v._pool_children():
        per_page = int(np.prod(pool.shape[v.lead + 1:])) * \
            jnp.dtype(pool.dtype).itemsize
        lead = int(np.prod(pool.shape[:v.lead], dtype=np.int64)) \
            if v.lead else 1
        total += lead * assigned * per_page
    return total


def view_touched_bytes(views: Dict[str, FieldView]) -> int:
    """HBM bytes a layout-native decode step actually touches: assigned
    pages (+ scale pages + the table) for paged fields, the physical
    buffers for the rest.  Shared pages (prefix sharing: one page mapped
    by several slots' tables) are counted once.  Host-side accounting
    (reads the page table); used by ``benchmarks/bench_inference``."""
    total = 0
    for v in views.values():
        if isinstance(v, PagedView):
            total += _paged_assigned_bytes(v)
            pt = np.asarray(v.page_table)
            total += pt.size * pt.dtype.itemsize
        else:
            children = (v.q, v.scale) if isinstance(v, QuantView) \
                else (v.data,)
            total += sum(int(np.prod(c.shape)) *
                         jnp.dtype(c.dtype).itemsize for c in children)
    return total


def assigned_kv_bytes(views: Dict[str, FieldView]) -> int:
    """KV bytes actually REFERENCED by the live page tables: paged fields
    count their unique assigned pages (a prefix-shared page is stored —
    and counted — once), non-paged fields their full physical buffers.
    The prefix-sharing headline metric: physical cache that scales with
    *distinct* context, not with slot count."""
    total = 0
    for v in views.values():
        if isinstance(v, PagedView):
            total += _paged_assigned_bytes(v)
        else:
            children = (v.q, v.scale) if isinstance(v, QuantView) \
                else (v.data,)
            total += sum(int(np.prod(c.shape)) *
                         jnp.dtype(c.dtype).itemsize for c in children)
    return total


# ---------------------------------------------------------------------------
# Dense (base: generic pack-through + per-field slot surgery)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DenseLayout:
    """Physical == logical.  Also the base class providing the generic
    per-field slot surgery used by the other layouts' pass-through
    fields."""

    name = "dense"

    # -- logical <-> physical ----------------------------------------------
    def pack(self, dense: Dict[str, Any], bk: Dict[str, Any],
             axes: Dict[str, int]) -> Dict[str, Any]:
        return dict(dense)

    def unpack(self, kv: Dict[str, Any], bk: Dict[str, Any],
               axes: Dict[str, int]) -> Dict[str, Any]:
        return dict(kv)

    # -- KVView -------------------------------------------------------------
    def view(self, kv: Dict[str, Any], bk: Dict[str, Any],
             axes: Dict[str, int]) -> Dict[str, FieldView]:
        return {f: DenseView(v, axes[f]) for f, v in kv.items()}

    # -- layout-owned bookkeeping ------------------------------------------
    def init_bookkeeping(self, slots: int) -> Dict[str, Any]:
        return {}

    def bookkeeping_axes(self) -> Dict[str, int]:
        return {}

    # -- slot surgery on the PHYSICAL representation -----------------------
    def _axis(self, field: str, axes: Dict[str, int]) -> int:
        return axes[_base_name(field)]

    def where_rows(self, rows: jax.Array, new_kv: Dict[str, Any],
                   old_kv: Dict[str, Any], bk: Dict[str, Any],
                   axes: Dict[str, int]) -> Dict[str, Any]:
        return {f: where_rows(rows, new_kv[f], old_kv[f],
                              self._axis(f, axes)) for f in new_kv}

    def write_slot(self, kv: Dict[str, Any], bk: Dict[str, Any],
                   slot: jax.Array, dense_row: Dict[str, Any],
                   axes: Dict[str, int],
                   page_mask: Optional[jax.Array] = None,
                   exclude: Tuple[str, ...] = ()) -> Dict[str, Any]:
        """Scatter a 1-slot dense row into physical slot ``slot``.
        ``page_mask`` is a paged-layout concern (tail-only admission
        writes under prefix sharing) — ignored for non-paged layouts,
        whose slots are exclusively owned by construction.  Fields whose
        base name is in ``exclude`` come through untouched (the chunked
        prefill streams length-axis KV in via :meth:`write_span`, so the
        finalising scatter writes only the remaining fields)."""
        packed = self.pack(dense_row, bk, axes)
        out = {}
        for f, dst in kv.items():
            if _base_name(f) in exclude:
                out[f] = dst
                continue
            src = packed[f].astype(dst.dtype)
            out[f] = jax.lax.dynamic_update_slice_in_dim(
                dst, src, slot, axis=self._axis(f, axes))
        return out

    # -- chunk-granular access (chunked prefill) ----------------------------
    def read_slot(self, kv: Dict[str, Any], bk: Dict[str, Any],
                  axes: Dict[str, int], slot: jax.Array) -> Dict[str, Any]:
        """Dense logical row (batch size 1) of slot ``slot`` — the
        KV-conditioned chunked prefill seeds its row cache from this
        (adopted prefix-shared pages included) so tail chunks attend the
        resident KV.  O(row) memory; an admission-path primitive, never
        on the decode hot path."""
        row = {f: jax.lax.dynamic_slice_in_dim(v, slot, 1,
                                               self._axis(f, axes))
               for f, v in kv.items()}
        return self.unpack(row, bk, axes)

    def write_span(self, kv: Dict[str, Any], bk: Dict[str, Any],
                   slot: jax.Array, fields: Dict[str, Any],
                   length_axes: Dict[str, int], axes: Dict[str, int],
                   start: jax.Array,
                   min_page: Optional[jax.Array] = None) -> Dict[str, Any]:
        """Write one prefill chunk's positions ``[start, start + C)`` of
        the given length-axis ``fields`` (dense logical, batch size 1)
        into slot ``slot`` — the chunk-granular page write.  For
        non-paged layouts this is a positional ``dynamic_update_slice``
        (quantizing layouts quantize the chunk on write); ``min_page``
        only applies to the paged override."""
        packed = self.pack(fields, bk, axes)
        out = dict(kv)
        for f, v in packed.items():
            if f not in kv:
                continue
            dst = kv[f]
            starts = [0] * dst.ndim
            starts[self._axis(f, axes)] = slot
            starts[length_axes[_base_name(f)]] = start
            out[f] = jax.lax.dynamic_update_slice(
                dst, v.astype(dst.dtype), tuple(starts))
        return out

    # -- slot snapshot / restore (session tiering) --------------------------
    def snapshot_slot(self, kv: Dict[str, Any], bk: Dict[str, Any],
                      axes: Dict[str, int],
                      slot: jax.Array) -> Dict[str, Any]:
        """Slot ``slot``'s kv in the PHYSICAL representation (batch dim
        kept at size 1): a plain batch-axis slice per field.  Unlike
        :meth:`read_slot` this never dequantises — an int8 slot
        snapshots as its ``__q``/``__scale`` rows, so the host tier
        holds it compressed and the restore is bit-exact."""
        return {f: jax.lax.dynamic_slice_in_dim(v, slot, 1,
                                                self._axis(f, axes))
                for f, v in kv.items()}

    def restore_slot(self, kv: Dict[str, Any], bk: Dict[str, Any],
                     axes: Dict[str, int], slot: jax.Array,
                     snap: Dict[str, Any]) -> Dict[str, Any]:
        """Exact inverse of :meth:`snapshot_slot`: scatter the physical
        snapshot back into slot ``slot`` (which need not be the slot it
        was taken from)."""
        return {f: jax.lax.dynamic_update_slice_in_dim(
                    dst, snap[f].astype(dst.dtype), slot,
                    axis=self._axis(f, axes))
                for f, dst in kv.items()}


# ---------------------------------------------------------------------------
# int8 with per-vector scales
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizedLayout(DenseLayout):
    """int8 KV + float32 per-vector scales (``f`` -> ``f__q``/``f__scale``).

    KV bytes shrink ~4x vs float32 (1 byte per element + 4/head_dim
    scale overhead); decode kernels read the int8 buffers through a
    :class:`QuantView` (dequant fused in-kernel on the Pallas path), so
    accuracy is within the symmetric-int8 rounding error (~0.4% of each
    vector's max magnitude per element — the documented tolerance).
    """

    fields: Tuple[str, ...] = ()
    dtype: str = "float32"
    name = "int8"

    def pack(self, dense, bk, axes):
        out = {}
        for f, v in dense.items():
            if f in self.fields:
                out[f + "__q"], out[f + "__scale"] = quantize_int8(v)
            else:
                out[f] = v
        return out

    def unpack(self, kv, bk, axes):
        out = {}
        for f, v in kv.items():
            if f.endswith("__q"):
                base = f[:-3]
                out[base] = dequantize_int8(v, kv[base + "__scale"],
                                            jnp.dtype(self.dtype))
            elif not f.endswith("__scale"):
                out[f] = v
        return out

    def view(self, kv, bk, axes):
        out: Dict[str, FieldView] = {}
        for f, v in kv.items():
            if f.endswith("__q"):
                base = f[:-3]
                out[base] = QuantView(v, kv[base + "__scale"], axes[base],
                                      self.dtype)
            elif not f.endswith("__scale"):
                out[f] = DenseView(v, axes[f])
        return out


# ---------------------------------------------------------------------------
# Paged (optionally with int8 pages: the "paged_int8" composition)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLayout(DenseLayout):
    """Length-axis KV buffers as fixed-size pages in a shared pool.

    For every paged field the dense (..., B, max_len, ...) buffer becomes
    a physical (..., pool_pages + 1, page, ...) pool — the extra page is
    TRASH: unassigned page-table entries point at it, so packs of
    unallocated regions land there and unpacks of them read garbage that
    the kernels' validity masks never touch.  One int32 page table
    ``layout__page_table`` (slots, pages_per_slot) in bookkeeping is
    shared by all paged fields.

    ``quant_fields`` non-empty is the **paged_int8** composition: those
    fields are first quantized (``f__q`` int8 + ``f__scale`` float32,
    per-vector), then any with a length axis is paged — int8 pages in
    the shared pool with the scales riding in a parallel scale pool.
    Quantized fields WITHOUT a length axis (e.g. the tconst ctx/gen
    windows) stay dense int8+scale buffers, as in
    :class:`QuantizedLayout`.

    Constraint (asserted): a paged field's batch axis must immediately
    precede its length axis, so page gather/scatter is a single take /
    indexed set.

    Fields absent from the cache (e.g. ``hist_k`` in pure-tconst mode)
    are skipped, making the layout a no-op for caches that are already
    O(1).
    """

    page: int = 64
    pool_pages: int = 0
    max_len: int = 0
    slots: int = 0
    fields: Tuple[Tuple[str, int], ...] = ()
    quant_fields: Tuple[str, ...] = ()
    dtype: str = "float32"

    @property
    def name(self) -> str:                             # type: ignore[override]
        return "paged_int8" if self.quant_fields else "paged"

    @property
    def pages_per_slot(self) -> int:
        return -(-self.max_len // self.page)

    @property
    def trash(self) -> int:
        return self.pool_pages

    @property
    def preallocated(self) -> bool:
        """Full pool: identity page table works with no allocator."""
        return self.pool_pages >= self.slots * self.pages_per_slot

    def _length_axis(self, field: str) -> Optional[int]:
        base = _base_name(field)
        for f, la in self.fields:
            if f == base:
                return la
        return None

    def pages_anything(self, kv_keys) -> bool:
        """True if any physical kv field is actually stored in pages."""
        return any(self._length_axis(f) is not None for f in kv_keys)

    def _quant_pack(self, dense: Dict[str, Any]) -> Dict[str, Any]:
        out = {}
        for f, v in dense.items():
            if f in self.quant_fields:
                out[f + "__q"], out[f + "__scale"] = quantize_int8(v)
            else:
                out[f] = v
        return out

    # -- bookkeeping --------------------------------------------------------
    def init_bookkeeping(self, slots):
        pps = self.pages_per_slot
        if self.preallocated:
            pt = jnp.arange(slots * pps, dtype=jnp.int32).reshape(slots, pps)
        else:
            pt = jnp.full((slots, pps), self.trash, jnp.int32)
        return {PAGE_TABLE: pt}

    def bookkeeping_axes(self):
        return {PAGE_TABLE: 0}

    # -- paging primitives --------------------------------------------------
    def _to_pages(self, x: jax.Array, la: int) -> jax.Array:
        """(..., B, L, rest) -> (..., B, pps, page, rest)."""
        pps = self.pages_per_slot
        pad = pps * self.page - x.shape[la]
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[la] = (0, pad)
            x = jnp.pad(x, widths)
        return x.reshape(x.shape[:la] + (pps, self.page) + x.shape[la + 1:])

    def pack(self, dense, bk, axes):
        pt = bk[PAGE_TABLE]
        out = {}
        for f, v in self._quant_pack(dense).items():
            la = self._length_axis(f)
            if la is None:
                out[f] = v
                continue
            assert self._axis(f, axes) == la - 1, (f, axes, la)
            pages = self._to_pages(v, la)          # (..., B, pps, page, rest)
            pool_shape = (v.shape[:la - 1] + (self.pool_pages + 1, self.page)
                          + v.shape[la + 1:])
            idx = (slice(None),) * (la - 1) + (pt,)
            out[f] = jnp.zeros(pool_shape, v.dtype).at[idx].set(pages)
        return out

    def unpack(self, kv, bk, axes):
        pt = bk[PAGE_TABLE]
        staged = {}
        for f, v in kv.items():
            la = self._length_axis(f)
            if la is None:
                staged[f] = v
                continue
            gathered = jnp.take(v, pt, axis=la - 1)  # (..., B, pps, page, r)
            merged = gathered.reshape(
                gathered.shape[:la] + (-1,) + gathered.shape[la + 2:])
            staged[f] = jax.lax.slice_in_dim(merged, 0, self.max_len, axis=la)
        out = {}
        for f, v in staged.items():
            if f.endswith("__q"):
                out[f[:-3]] = dequantize_int8(v, staged[f[:-3] + "__scale"],
                                              jnp.dtype(self.dtype))
            elif not f.endswith("__scale"):
                out[f] = v
        return out

    def view(self, kv, bk, axes):
        pt = bk[PAGE_TABLE]
        out: Dict[str, FieldView] = {}
        for f, v in kv.items():
            if f.endswith("__scale"):
                continue
            base = _base_name(f)
            if f.endswith("__q"):
                storage: FieldView = QuantView(v, kv[base + "__scale"],
                                               axes[base], self.dtype)
            else:
                storage = DenseView(v, axes[f])
            la = self._length_axis(f)
            if la is None:
                out[base] = storage
            else:
                out[base] = PagedView(storage, pt, self.page, self.max_len,
                                      self.trash, lead=la - 1)
        return out

    # -- slot surgery -------------------------------------------------------
    def where_rows(self, rows, new_kv, old_kv, bk, axes):
        pt = bk[PAGE_TABLE]
        # slot mask -> page mask over the pool (real pages are uniquely
        # owned; the trash page's pick is arbitrary and its content dead)
        page_rows = jnp.zeros((self.pool_pages + 1,), bool).at[pt].set(
            jnp.broadcast_to(rows[:, None], pt.shape))
        out = {}
        for f in new_kv:
            la = self._length_axis(f)
            if la is None:
                out[f] = where_rows(rows, new_kv[f], old_kv[f],
                                    self._axis(f, axes))
            else:
                out[f] = where_rows(page_rows, new_kv[f], old_kv[f], la - 1)
        return out

    def write_slot(self, kv, bk, slot, dense_row, axes, page_mask=None,
                   exclude=()):
        """Page-map surgery: only the slot's own pages are touched.

        ``page_mask`` (pps,) bool selects which of the slot's table
        entries are written; masked-out entries are redirected to the
        TRASH page, so a prefix-SHARED page (refcount > 1, content
        already resident and correct) is never written by admission —
        the copy-on-write contract's tail-only prefill write.
        ``exclude`` skips fields by base name (chunked prefill: the
        length-axis KV was already streamed in by :meth:`write_span`)."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)      # (pps,)
        if page_mask is not None:
            pt_row = jnp.where(page_mask, pt_row, self.trash)
        packed = self._quant_pack(dense_row)
        out = {}
        for f, dst in kv.items():
            if _base_name(f) in exclude:
                out[f] = dst
                continue
            la = self._length_axis(f)
            src = packed[f].astype(dst.dtype)
            if la is None:
                out[f] = jax.lax.dynamic_update_slice_in_dim(
                    dst, src, slot, axis=self._axis(f, axes))
                continue
            pages = self._to_pages(src, la)       # (..., 1, pps, page, rest)
            pages = jax.lax.index_in_dim(pages, 0, axis=la - 1,
                                         keepdims=False)
            idx = (slice(None),) * (la - 1) + (pt_row,)
            out[f] = dst.at[idx].set(pages)
        return out

    # -- chunk-granular access (chunked prefill) ----------------------------
    def read_slot(self, kv, bk, axes, slot):
        """Dense logical row of slot ``slot``, gathered through its OWN
        page-table row only — other slots' pages are never touched.
        Table entries at TRASH read garbage; the chunked-prefill seeding
        masks everything beyond the resident prefix."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)       # (pps,)
        staged = {}
        for f, v in kv.items():
            la = self._length_axis(f)
            if la is None:
                staged[f] = jax.lax.dynamic_slice_in_dim(
                    v, slot, 1, self._axis(f, axes))
                continue
            g = jnp.take(v, pt_row, axis=la - 1)   # (..., pps, page, rest)
            g = jnp.expand_dims(g, la - 1)         # batch dim of 1
            merged = g.reshape(g.shape[:la] + (-1,) + g.shape[la + 2:])
            staged[f] = jax.lax.slice_in_dim(merged, 0, self.max_len,
                                             axis=la)
        out = {}
        for f, v in staged.items():
            if f.endswith("__q"):
                out[f[:-3]] = dequantize_int8(v, staged[f[:-3] + "__scale"],
                                              jnp.dtype(self.dtype))
            elif not f.endswith("__scale"):
                out[f] = v
        return out

    def write_span(self, kv, bk, slot, fields, length_axes, axes, start,
                   min_page=None):
        """THE chunk-granular page write: a prefill chunk covering
        positions ``[start, start + C)`` — ``start`` page-aligned, ``C``
        a page-size multiple, so the span is exactly ``C // page`` whole
        pages of the slot's table — is scattered onto those pool pages
        (int8 pools quantize on write, scales ride along).  Table
        entries below ``min_page`` (pages ADOPTED from the prefix map,
        refcount > 1) are redirected to TRASH: a chunked admission that
        recomputes part of a resident prefix (e.g. a fully-resident
        prompt still needs one chunk forwarded for its logits) can never
        violate the copy-on-write invariant."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)       # (pps,)
        out = dict(kv)
        for f, v in self._quant_pack(fields).items():
            if f not in kv:
                continue
            dst = kv[f]
            la = self._length_axis(f)
            assert la is not None, \
                (f, "write_span takes length-axis fields only")
            C = v.shape[la]
            assert C % self.page == 0, \
                (f, C, self.page, "chunk must be a page-size multiple")
            m = C // self.page
            first = start // self.page
            pages = jax.lax.dynamic_slice_in_dim(pt_row, first, m)
            if min_page is not None:
                pages = jnp.where(first + jnp.arange(m) >= min_page,
                                  pages, self.trash)
            vv = jax.lax.index_in_dim(v.astype(dst.dtype), 0, axis=la - 1,
                                      keepdims=False)
            vv = vv.reshape(vv.shape[:la - 1] + (m, self.page)
                            + vv.shape[la:])
            idx = (slice(None),) * (la - 1) + (pages,)
            out[f] = dst.at[idx].set(vv)
        return out

    # -- slot snapshot / restore (session tiering) --------------------------
    def page_axis(self, field: str) -> Optional[int]:
        """Physical pool page axis of ``field`` (None for fields that are
        not paged) — where a slot snapshot's gathered page stack lives,
        so the scheduler can trim/pad it on the host."""
        la = self._length_axis(field)
        return None if la is None else la - 1

    def snapshot_slot(self, kv, bk, axes, slot):
        """Physical slot snapshot: paged fields gather EXACTLY the
        slot's page-table row out of the pool — ``(..., pps, page,
        rest)`` page stacks, int8 pools with their scale pools alongside
        — and non-paged fields fall back to the batch-axis row slice.
        Table entries at TRASH gather garbage pages; the host side trims
        the stack to the slot's live page count, and a restore through a
        fresh table row re-masks whatever padding comes back."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)        # (pps,)
        out = {}
        for f, v in kv.items():
            la = self._length_axis(f)
            if la is None:
                out[f] = jax.lax.dynamic_slice_in_dim(
                    v, slot, 1, self._axis(f, axes))
            else:
                out[f] = jnp.take(v, pt_row, axis=la - 1)
        return out

    def restore_slot(self, kv, bk, axes, slot, snap):
        """Scatter a snapshot back through slot ``slot``'s CURRENT
        page-table row (the restoring scheduler assigns fresh,
        exclusively-owned pages first, so no shared page can be hit;
        entries at TRASH make the matching snapshot pages dead
        writes)."""
        pt_row = jnp.take(bk[PAGE_TABLE], slot, axis=0)        # (pps,)
        out = {}
        for f, dst in kv.items():
            la = self._length_axis(f)
            if la is None:
                out[f] = jax.lax.dynamic_update_slice_in_dim(
                    dst, snap[f].astype(dst.dtype), slot,
                    axis=self._axis(f, axes))
            else:
                ix = (slice(None),) * (la - 1) + (pt_row,)
                out[f] = dst.at[ix].set(snap[f].astype(dst.dtype))
        return out

    def gather_pages(self, kv: Dict[str, Any],
                     pages: jax.Array) -> Dict[str, Any]:
        """Pool pages ``pages`` (k,) of every paged field as host-bound
        page stacks — how refcount-0 prefix pages RETIRE into the tier
        store.  Pad ``pages`` with the trash index for a fixed arity
        (the padding gathers garbage the caller drops)."""
        out = {}
        for f, v in kv.items():
            la = self._length_axis(f)
            if la is not None:
                out[f] = jnp.take(v, pages, axis=la - 1)
        return out

    def scatter_pages(self, kv: Dict[str, Any], pages: jax.Array,
                      contents: Dict[str, Any]) -> Dict[str, Any]:
        """Inverse of :meth:`gather_pages`: upload page ``contents``
        onto pool pages ``pages`` (k,) — how retired prefix-page content
        is RE-ADOPTED from the store onto a fresh page during admission.
        Trash-padded entries are dead writes, as in :meth:`fork_pages`."""
        out = dict(kv)
        for f, v in contents.items():
            la = self._length_axis(f)
            assert la is not None, (f, "scatter_pages takes paged fields")
            dst = kv[f]
            ix = (slice(None),) * (la - 1) + (pages,)
            out[f] = dst.at[ix].set(v.astype(dst.dtype))
        return out

    # -- copy-on-write forking ----------------------------------------------
    def fork_pages(self, kv: Dict[str, Any], src: jax.Array,
                   dst: jax.Array) -> Dict[str, Any]:
        """Device-side page fork: copy pool pages ``src`` (k,) onto fresh
        pool pages ``dst`` (k,) for EVERY paged field (int8 pools carry
        their scale pool along).  The scheduler calls this before a slot
        that references shared (refcount > 1) pages can write them — the
        chunk/admission-boundary copy-on-write.  Pad ``src``/``dst``
        with the trash index for a fixed arity (trash -> trash copies
        are dead writes), so the jitted fork compiles once."""
        out = dict(kv)
        for f, pool in kv.items():
            la = self._length_axis(f)
            if la is None:
                continue
            taken = jnp.take(pool, src, axis=la - 1)
            ix = (slice(None),) * (la - 1) + (dst,)
            out[f] = pool.at[ix].set(taken)
        return out

"""Decoder-only language model covering the dense / moe / ssm / hybrid /
vlm assigned architectures.

Design notes
------------
* **Scan over layers.**  Homogeneous layers are parameter-stacked (leading
  ``n_scan`` dim) and driven by ``jax.lax.scan`` so the 126-layer llama3
  lowers to a compact HLO.  Per-layer heterogeneity (gemma3's 5 local : 1
  global pattern, hymba's window pattern) is expressed as a *traced* int32
  ``window`` array riding the scan — masks are position arithmetic, so no
  unrolling is needed.  DeepSeek's leading dense layers differ in
  parameter *shape* and are unrolled separately (``dense_layers``).
* **Attention dispatch.**  Sequences longer than ``FLASH_THRESHOLD`` route
  through the blocked flash implementation (O(L·block) memory); short ones
  use the naive reference.  Both are numerically interchangeable (tested).
* **Remat.**  The scanned layer body is wrapped in ``jax.checkpoint`` for
  training so the dry-run memory analysis reflects a production
  activation-recompute policy.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.layers import attention as A
from repro.layers import embed as E
from repro.layers import rope as R
from repro.layers import ssm as S
from repro.layers.common import (Params, init_rmsnorm, rmsnorm, split_keys)
from repro.layers.mlp import init_swiglu, swiglu
from repro.layers.moe import init_moe, moe_ffn
from repro.kernels.xla_flash import flash_attention

FLASH_THRESHOLD = 2048      # min L_q*L_k elements^(1/2) to use blocked path


# ---------------------------------------------------------------------------
# Layer windows (per-layer sliding window; 0 = full causal)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    n = cfg.n_layers
    if cfg.local_global_ratio > 0:
        # gemma3: `ratio` local layers then 1 global, repeating
        period = cfg.local_global_ratio + 1
        w = np.array([0 if (i % period) == cfg.local_global_ratio
                      else cfg.sliding_window for i in range(n)], np.int32)
        return w
    if cfg.attention_mode == "sliding" and cfg.sliding_window > 0:
        return np.full((n,), cfg.sliding_window, np.int32)
    return np.zeros((n,), np.int32)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _init_layer(key: jax.Array, cfg: ModelConfig, moe: bool) -> Params:
    ka, kf, ks = split_keys(key, 3)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    if cfg.arch_type == "ssm":
        p["ssm"] = S.init_ssm(ka, cfg)
        return p
    p["attn"] = A.init_attention(ka, cfg)
    if cfg.hybrid_parallel:
        p["ssm"] = S.init_ssm(ks, cfg)
    p["ln2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    if moe:
        p["ffn"] = init_moe(kf, cfg)
    else:
        p["ffn"] = init_swiglu(kf, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def init_lm(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kd, kl = split_keys(key, 3)
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.n_layers - n_dense
    params: Params = {"embed": E.init_embed(ke, cfg)}
    if n_dense:
        dkeys = split_keys(kd, n_dense)
        params["dense_layers"] = [
            _init_layer(k, cfg, moe=False) for k in dkeys]
    lkeys = jax.random.split(kl, n_scan)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, moe=cfg.is_moe))(lkeys)
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    return params


# ---------------------------------------------------------------------------
# Shared layer body
# ---------------------------------------------------------------------------


def _self_attention(layer: Params, xn: jax.Array, pos: jax.Array,
                    window: jax.Array, cfg: ModelConfig,
                    cos: jax.Array, sin: jax.Array) -> jax.Array:
    """pos: (L,) SHARED positions (1-D keeps flash masks head/batch-free)."""
    dtype = xn.dtype
    q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
    q = R.apply_rope(q, cos, sin)
    k = R.apply_rope(k, cos, sin)
    L = xn.shape[1]
    if L >= FLASH_THRESHOLD:
        o = flash_attention(q, k, v, pos, pos, window, True,
                            cfg.logit_softcap, 512, 512)
    else:
        mask = A.make_mask(pos, pos, "sliding", window)
        o = A.sdpa(q, k, v, mask, cfg.logit_softcap)
    return A.out_proj(layer["attn"], o, dtype)


def _ffn(layer: Params, xn: jax.Array, cfg: ModelConfig, moe: bool
         ) -> Tuple[jax.Array, jax.Array]:
    if moe:
        return moe_ffn(layer["ffn"], xn, cfg)
    return swiglu(layer["ffn"], xn), jnp.zeros((), jnp.float32)


def _layer_fwd(layer: Params, x: jax.Array, pos: jax.Array,
               window: jax.Array, cfg: ModelConfig, moe: bool,
               cos: jax.Array, sin: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence layer forward. Returns (x, aux)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    xn = rmsnorm(layer["ln1"], x, eps)
    if cfg.arch_type == "ssm":
        out, _ = S.ssm_mixer(layer["ssm"], xn, cfg)
        return x + out, aux
    out = _self_attention(layer, xn, pos, window, cfg, cos, sin)
    if cfg.hybrid_parallel:
        ssm_out, _ = S.ssm_mixer(layer["ssm"], xn, cfg)
        out = (out + ssm_out) * 0.5          # hymba: mean-fuse parallel heads
    x = x + out
    f, aux = _ffn(layer, rmsnorm(layer["ln2"], x, eps), cfg, moe)
    return x + f, aux


# ---------------------------------------------------------------------------
# Full forward (training / prefill trunk)
# ---------------------------------------------------------------------------


def embed_inputs(params: Params, tokens: jax.Array, cfg: ModelConfig,
                 vision_embeds: Optional[jax.Array] = None,
                 vision_mask: Optional[jax.Array] = None) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = E.embed_tokens(params["embed"], tokens, dtype)
    if vision_embeds is not None:
        pv = E.project_frontend(params["embed"], vision_embeds.astype(dtype))
        Tv = pv.shape[1]
        idx = jnp.clip(jnp.cumsum(vision_mask, axis=1) - 1, 0, Tv - 1)
        gathered = jnp.take_along_axis(pv, idx[..., None], axis=1)
        x = jnp.where(vision_mask[..., None], gathered, x)
    return x


def _rope_tables(cfg: ModelConfig, pos: jax.Array,
                 positions3: Optional[jax.Array]):
    hd = cfg.resolved_head_dim
    if cfg.mrope and cfg.mrope_sections:
        p3 = positions3 if positions3 is not None else R.text_positions3(pos)
        return R.mrope_cos_sin(p3, hd, cfg.rope_theta, cfg.mrope_sections)
    return R.rope_cos_sin(pos, hd, cfg.rope_theta)


def lm_forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
               positions3: Optional[jax.Array] = None,
               vision_embeds: Optional[jax.Array] = None,
               vision_mask: Optional[jax.Array] = None,
               remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced forward.  tokens: (B, L) -> (logits (B, L, V), aux)."""
    from repro.sharding.rules import shard_act
    B, L = tokens.shape
    x = embed_inputs(params, tokens, cfg, vision_embeds, vision_mask)
    x = shard_act(x)
    pos = jnp.arange(L, dtype=jnp.int32)          # shared 1-D positions
    cos, sin = _rope_tables(cfg, pos, positions3)
    windows = jnp.asarray(layer_windows(cfg))
    aux = jnp.zeros((), jnp.float32)

    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    for i, layer in enumerate(params.get("dense_layers", [])):
        x, a = _layer_fwd(layer, x, pos, windows[i], cfg, False, cos, sin)
        aux = aux + a

    def body(carry, xs):
        x, aux = carry
        layer, window = xs
        x = shard_act(x)
        x, a = _layer_fwd(layer, x, pos, window, cfg, cfg.is_moe, cos, sin)
        x = shard_act(x)
        return (x, aux + a), None

    n_scan = cfg.n_layers - n_dense
    scan_xs = (params["layers"], windows[n_dense:])
    group = _remat_group(n_scan) if remat else 1
    if remat and group > 1:
        # Nested (sqrt-depth) remat: only n_scan/group boundary activations
        # are saved for the backward pass; each group's inner carries are
        # recomputed from its boundary.  Cuts the 126-layer llama3 saved-
        # activation footprint by ~9x (EXPERIMENTS.md §Perf iteration 2).
        ng = n_scan // group
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((ng, group) + a.shape[1:]), scan_xs)

        @jax.checkpoint
        def group_body(carry, xs):
            # barrier: stop XLA from hoisting f32 converts across the
            # saved boundary stack (measured: it duplicated every saved
            # carry in f32 — §Perf H1 it4)
            carry = jax.lax.optimization_barrier(carry)
            return jax.lax.scan(body, carry, xs)

        (x, aux), _ = jax.lax.scan(group_body, (x, aux), grouped)
    else:
        body_fn = jax.checkpoint(body) if remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), scan_xs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)
    return logits, aux


def _remat_group(n: int) -> int:
    """Divisor of n closest to sqrt(n) (1 if n is small)."""
    if n < 16:
        return 1
    target = n ** 0.5
    divs = [d for d in range(2, n) if n % d == 0]
    return min(divs, key=lambda d: abs(d - target)) if divs else 1


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


# Cache partition for the serving layer (repro.models.api.DecodeState):
# true KV/recurrent state (counted in Fig-8g bytes) vs bookkeeping, and the
# batch ("slot") axis of every entry.
KV_KEYS = ("k", "v", "dense_k", "dense_v", "ssm", "conv")
CACHE_BATCH_AXES = {
    "len": 0, "done": 0, "k": 1, "v": 1, "dense_k": 1, "dense_v": 1,
    "ssm": 1, "conv": 1,
}

# Cache-layout metadata (repro.models.layouts): the growing max_len-axis
# KV buffers a PagedLayout pages, and the float KV a QuantizedLayout may
# store as int8 (the ssm recurrent state is mutated every step, so
# requantizing it would accumulate error — it stays dense).
LENGTH_AXES = {"k": 2, "v": 2, "dense_k": 2, "dense_v": 2}
QUANT_FIELDS = ("k", "v", "dense_k", "dense_v")


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int
                  ) -> Dict[str, Any]:
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.n_layers - n_dense
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32),
                             "done": jnp.zeros((batch,), bool)}
    if cfg.arch_type != "ssm":
        cache["k"] = jnp.zeros((n_scan, batch, max_len, kv, hd), dt)
        cache["v"] = jnp.zeros((n_scan, batch, max_len, kv, hd), dt)
        if n_dense:
            cache["dense_k"] = jnp.zeros((n_dense, batch, max_len, kv, hd), dt)
            cache["dense_v"] = jnp.zeros((n_dense, batch, max_len, kv, hd), dt)
    if cfg.arch_type == "ssm" or cfg.hybrid_parallel:
        dims = S.ssm_dims(cfg)
        cache["ssm"] = jnp.zeros(
            (n_scan, batch, dims.n_heads, dims.head_dim, dims.n_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_scan, batch, dims.d_conv - 1, dims.conv_dim), dt)
    return cache


def _layer_decode(layer: Params, x: jax.Array, cache_slice: Dict[str, Any],
                  cache_len: jax.Array, window: jax.Array, cfg: ModelConfig,
                  moe: bool, cos: jax.Array, sin: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Per-layer decode over KVViews: ``cache_slice`` holds the per-layer
    ``repro.models.layouts`` FieldViews, so the attention walks the
    physical representation (paged pool / int8) directly."""
    from repro.models import layouts as LT
    eps = cfg.norm_eps
    new_slice: Dict[str, Any] = {}
    xn = rmsnorm(layer["ln1"], x, eps)

    def run_ssm():
        st = {"ssm": cache_slice["ssm"].dense(),
              "conv": cache_slice["conv"].dense()}
        out, st = S.ssm_mixer(layer["ssm"], xn, cfg, state=st)
        # the recurrent state is never quantized/paged (mutated every
        # step), so a fresh DenseView is the identity re-wrap
        return out, {"ssm": LT.DenseView(st["ssm"]),
                     "conv": LT.DenseView(st["conv"])}

    if cfg.arch_type == "ssm":
        out, st = run_ssm()
        new_slice.update(st)
        return x + out, new_slice
    out, k_view, v_view = A.decode_attend_view(
        layer["attn"], xn, cache_slice["k"], cache_slice["v"], cache_len,
        cos, sin, cfg.logit_softcap, window)
    new_slice["k"], new_slice["v"] = k_view, v_view
    if cfg.hybrid_parallel:
        ssm_out, st = run_ssm()
        new_slice.update(st)
        out = (out + ssm_out) * 0.5
    x = x + out
    f, _ = _ffn(layer, rmsnorm(layer["ln2"], x, eps), cfg, moe)
    return x + f, new_slice


def lm_decode_step_views(params: Params, cache: Dict[str, Any],
                         token: jax.Array, cfg: ModelConfig,
                         positions3: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Layout-native one-token decode.  ``cache`` maps bookkeeping names
    to plain arrays and KV names to FieldViews; under the paged layout a
    step appends through the page table and attends page-by-page —
    nothing materialises the dense (layers, B, max_len, KV, D) view.
    token: (B,) -> (logits (B, V), cache)."""
    B = token.shape[0]
    dtype = jnp.dtype(cfg.dtype)
    x = E.embed_tokens(params["embed"], token[:, None], dtype)
    pos = cache["len"][:, None]
    cos, sin = _rope_tables(cfg, pos, positions3)
    windows = jnp.asarray(layer_windows(cfg))
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0

    cache = dict(cache)
    for i, layer in enumerate(params.get("dense_layers", [])):
        sl = {"k": cache["dense_k"].layer(i), "v": cache["dense_v"].layer(i)}
        x, new = _layer_decode(layer, x, sl, cache["len"], windows[i], cfg,
                               False, cos, sin)
        cache["dense_k"] = cache["dense_k"].set_layer(i, new["k"])
        cache["dense_v"] = cache["dense_v"].set_layer(i, new["v"])

    # fori_loop with the stacked cache VIEWS as CARRY, updated in place —
    # a lax.scan with cache slices as ys would stack a SECOND full cache
    # as its output (measured: ~2x decode peak on llama3-405b decode_32k,
    # EXPERIMENTS.md §Beyond-paper).  Views are registered pytrees, so
    # they ride the carry; ``set_layer`` writes only layer i's slice of
    # the physical buffers.
    keys = []
    if cfg.arch_type != "ssm":
        keys += ["k", "v"]
    if cfg.arch_type == "ssm" or cfg.hybrid_parallel:
        keys += ["ssm", "conv"]
    scan_windows = jnp.asarray(windows[n_dense:])

    def body(i, carry):
        x, bufs = carry
        layer = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        slc = {k: bufs[k].layer(i) for k in keys}
        x, new = _layer_decode(layer, x, slc, cache["len"],
                               scan_windows[i], cfg, cfg.is_moe, cos, sin)
        bufs = {k: bufs[k].set_layer(i, new[k]) for k in keys}
        return (x, bufs)

    n_scan = cfg.n_layers - n_dense
    x, bufs = jax.lax.fori_loop(
        0, n_scan, body, (x, {k: cache[k] for k in keys}))
    for k in keys:
        cache[k] = bufs[k]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)[:, 0]
    cache["len"] = cache["len"] + 1
    return logits, cache


def lm_decode_step(params: Params, cache: Dict[str, Any], token: jax.Array,
                   cfg: ModelConfig,
                   positions3: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Dense-dict one-token decode: legacy entry point and the parity
    oracle for the layout-native kernels (DenseView dispatch is
    bit-identical to the historic dense path)."""
    from repro.models import layouts as LT
    views = {k: LT.DenseView(v, CACHE_BATCH_AXES[k]) if k in KV_KEYS else v
             for k, v in cache.items()}
    logits, out = lm_decode_step_views(params, views, token, cfg, positions3)
    return logits, {k: v.dense() if isinstance(v, LT.FieldView) else v
                    for k, v in out.items()}


def lm_verify_chunk_views(params: Params, cache: Dict[str, Any],
                          feed: jax.Array, cfg: ModelConfig
                          ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Speculative VERIFY: score C fed tokens per slot in ONE
    fixed-shape dispatch — :func:`lm_decode_step_views` with the
    sequential C-step loop collapsed into a single
    :func:`repro.kernels.ops.prefill_chunk_attention` per layer.

    feed: (B, C) int32 — position c is what the sequential decode would
    feed at ``len + c``.  All C keys/values are written through the
    views at positions ``len + c`` (exactly the sequential write
    sites); ``len`` is NOT advanced — acceptance of an m-prefix is a
    later ``len += m`` and the rejected suffix becomes stale garbage
    beyond ``len``, masked by causality here and overwritten by the
    next round before it could ever be attended.  Recurrent-state
    families (ssm / hybrid) cannot roll back and are excluded by
    :meth:`DenseDecode.supports_speculative`.

    Returns (logits (B, C, V), cache — counters untouched).
    """
    from repro.kernels import ops
    assert cfg.arch_type != "ssm" and not cfg.hybrid_parallel, \
        "recurrent state cannot be rolled back by a length decrement"
    B, C = feed.shape
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    x = E.embed_tokens(params["embed"], feed, dtype)             # (B, C, D)
    pos = cache["len"][:, None] + \
        jnp.arange(C, dtype=jnp.int32)[None]                     # (B, C)
    cos, sin = _rope_tables(cfg, pos, None)
    windows = jnp.asarray(layer_windows(cfg))
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0

    def layer_verify(layer, x, kv, vv, window, moe):
        xn = rmsnorm(layer["ln1"], x, eps)
        q, k_new, v_new = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k_new = R.apply_rope(k_new, cos, sin)
        for c in range(C):
            kv = kv.write_token(cache["len"] + c, k_new[:, c])
            vv = vv.write_token(cache["len"] + c, v_new[:, c])
        kd = kv.dense().astype(dtype)
        kpos = jnp.arange(kd.shape[1], dtype=jnp.int32)
        o = ops.prefill_chunk_attention(q, kd, vv.dense().astype(dtype),
                                        pos, kpos, window,
                                        cfg.logit_softcap)
        x = x + A.out_proj(layer["attn"], o, dtype)
        f, _ = _ffn(layer, rmsnorm(layer["ln2"], x, eps), cfg, moe)
        return x + f, kv, vv

    cache = dict(cache)
    for i, layer in enumerate(params.get("dense_layers", [])):
        x, nk, nv = layer_verify(layer, x, cache["dense_k"].layer(i),
                                 cache["dense_v"].layer(i), windows[i],
                                 False)
        cache["dense_k"] = cache["dense_k"].set_layer(i, nk)
        cache["dense_v"] = cache["dense_v"].set_layer(i, nv)

    scan_windows = jnp.asarray(windows[n_dense:])

    def body(i, carry):
        x, kb, vb = carry
        layer = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        x, nk, nv = layer_verify(layer, x, kb.layer(i), vb.layer(i),
                                 scan_windows[i], cfg.is_moe)
        return (x, kb.set_layer(i, nk), vb.set_layer(i, nv))

    x, kb, vb = jax.lax.fori_loop(
        0, cfg.n_layers - n_dense, body, (x, cache["k"], cache["v"]))
    cache["k"], cache["v"] = kb, vb
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)   # (B, C, V)
    return logits, cache


def lm_prefill_chunk(params: Params, row: Dict[str, Any],
                     tokens: jax.Array, start: jax.Array,
                     n_valid: jax.Array, cfg: ModelConfig
                     ) -> Tuple[jax.Array, Dict[str, Any], Dict[str, Any]]:
    """One fixed-shape chunk of the chunked (KV-conditioned) prefill.

    ``row`` is the dense (batch 1) row cache: k/v buffers with positions
    ``[0, start)`` already written — the resident prefix seeded from
    adopted prefix-shared pages plus every earlier chunk — and the
    ssm/conv recurrent state advanced to ``start``.  The chunk's C
    queries attend those resident positions AND the chunk itself
    (causal / per-layer sliding windows, positions are true token
    positions, so the result matches the one-shot :func:`lm_prefill` up
    to float association); its K/V is appended into the row cache and
    also returned per length-axis field for the chunk-granular
    ``write_span`` into the slot's layout.

    tokens: (B, C) int32 (trailing zero padding allowed — padded
    positions sit beyond every real query causally and beyond ``len``
    afterwards, and ``n_valid`` — the TOTAL prompt length — keeps them
    out of the recurrent ssm/conv state); start: traced scalar int32.
    Returns (logits (B, C, V), row, chunk_kv {field: (layers, B, C,
    KV, D)}).
    """
    from repro.kernels import ops
    from repro.sharding.rules import shard_act
    B, C = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    eps = cfg.norm_eps
    x = shard_act(E.embed_tokens(params["embed"], tokens, dtype))
    pos = start + jnp.arange(C, dtype=jnp.int32)
    cos, sin = _rope_tables(cfg, pos, None)
    windows = jnp.asarray(layer_windows(cfg))
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    has_attn = cfg.arch_type != "ssm"
    has_ssm = cfg.arch_type == "ssm" or cfg.hybrid_parallel
    # real tokens in THIS chunk (the last chunk is zero-padded)
    in_chunk = jnp.clip(n_valid - start, 0, C)
    vl = jnp.broadcast_to(in_chunk, (B,))
    row = dict(row)

    def chunk_layer(layer, x, window, moe, k_row=None, v_row=None,
                    ssm_st=None):
        xn = rmsnorm(layer["ln1"], x, eps)
        new_st = None
        ssm_out = None
        if ssm_st is not None:
            ssm_out, new_st = S.ssm_mixer(layer["ssm"], xn, cfg,
                                          state=ssm_st, valid_len=vl)
        if not has_attn:
            return x + ssm_out, None, None, None, None, new_st
        q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k = R.apply_rope(k, cos, sin)
        k_row = jax.lax.dynamic_update_slice_in_dim(
            k_row, k.astype(k_row.dtype), start, axis=1)
        v_row = jax.lax.dynamic_update_slice_in_dim(
            v_row, v.astype(v_row.dtype), start, axis=1)
        kpos = jnp.arange(k_row.shape[1], dtype=jnp.int32)
        o = ops.prefill_chunk_attention(q, k_row, v_row, pos, kpos,
                                        window, cfg.logit_softcap)
        out = A.out_proj(layer["attn"], o, dtype)
        if cfg.hybrid_parallel:
            out = (out + ssm_out) * 0.5
        x = x + out
        f, _ = _ffn(layer, rmsnorm(layer["ln2"], x, eps), cfg, moe)
        return x + f, k_row, v_row, k, v, new_st

    chunk_kv: Dict[str, Any] = {}
    dk_c, dv_c = [], []
    for i, layer in enumerate(params.get("dense_layers", [])):
        x, kr, vr, kc, vc, _ = chunk_layer(
            layer, x, windows[i], False,
            row["dense_k"][i], row["dense_v"][i])
        row["dense_k"] = row["dense_k"].at[i].set(kr)
        row["dense_v"] = row["dense_v"].at[i].set(vr)
        dk_c.append(kc)
        dv_c.append(vc)
    if dk_c:
        chunk_kv["dense_k"] = jnp.stack(dk_c)
        chunk_kv["dense_v"] = jnp.stack(dv_c)

    xs: Dict[str, Any] = {"layer": params["layers"],
                          "window": windows[n_dense:]}
    if has_attn:
        xs["k"], xs["v"] = row["k"], row["v"]
    if has_ssm:
        xs["ssm"], xs["conv"] = row["ssm"], row["conv"]

    def body(x, xs_i):
        st = {"ssm": xs_i["ssm"], "conv": xs_i["conv"]} if has_ssm else None
        x, kr, vr, kc, vc, new_st = chunk_layer(
            xs_i["layer"], shard_act(x), xs_i["window"], cfg.is_moe,
            xs_i.get("k"), xs_i.get("v"), st)
        ys = {}
        if has_attn:
            ys.update(k=kr, v=vr, kc=kc, vc=vc)
        if has_ssm:
            ys.update(ssm=new_st["ssm"], conv=new_st["conv"])
        return x, ys

    x, ys = jax.lax.scan(body, x, xs)
    if has_attn:
        row["k"], row["v"] = ys["k"], ys["v"]
        chunk_kv["k"], chunk_kv["v"] = ys["kc"], ys["vc"]
    if has_ssm:
        row["ssm"], row["conv"] = ys["ssm"], ys["conv"]
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x, cfg.logit_softcap)
    return logits, row, chunk_kv


def lm_prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
               max_len: int,
               positions3: Optional[jax.Array] = None,
               vision_embeds: Optional[jax.Array] = None,
               vision_mask: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Process a prompt, filling the KV cache.  Returns (last logits, cache).

    Implemented as the full forward plus K/V capture (single pass; the
    capture rides the layer scan).
    """
    from repro.sharding.rules import shard_act
    B, L = tokens.shape
    x = embed_inputs(params, tokens, cfg, vision_embeds, vision_mask)
    x = shard_act(x)
    pos = jnp.arange(L, dtype=jnp.int32)          # shared 1-D positions
    cos, sin = _rope_tables(cfg, pos, positions3)
    windows = jnp.asarray(layer_windows(cfg))
    n_dense = cfg.first_dense_layers if cfg.is_moe else 0
    cache = init_kv_cache(cfg, B, max_len)
    eps = cfg.norm_eps
    dtype = jnp.dtype(cfg.dtype)

    def capture_layer(layer, x, window, moe):
        """Layer fwd that also returns this layer's K/V (and ssm state)."""
        out_extras: Dict[str, Any] = {}
        xn = rmsnorm(layer["ln1"], x, eps)
        if cfg.arch_type == "ssm" or cfg.hybrid_parallel:
            st0 = {"ssm": jnp.zeros_like(cache["ssm"][0]),
                   "conv": jnp.zeros_like(cache["conv"][0])}
            ssm_out, st = S.ssm_mixer(layer["ssm"], xn, cfg, state=st0)
            out_extras["ssm"], out_extras["conv"] = st["ssm"], st["conv"]
        if cfg.arch_type == "ssm":
            return x + ssm_out, out_extras
        q, k, v = A.qkv_proj(layer["attn"], xn, xn, dtype)
        q = R.apply_rope(q, cos, sin)
        k = R.apply_rope(k, cos, sin)
        kf = jnp.zeros((B, max_len) + k.shape[2:], dtype)
        vf = jnp.zeros((B, max_len) + v.shape[2:], dtype)
        out_extras["k"] = jax.lax.dynamic_update_slice_in_dim(kf, k, 0, 1)
        out_extras["v"] = jax.lax.dynamic_update_slice_in_dim(vf, v, 0, 1)
        if L >= FLASH_THRESHOLD:
            o = flash_attention(q, k, v, pos, pos, window, True,
                                cfg.logit_softcap, 512, 512)
        else:
            o = A.sdpa(q, k, v, A.make_mask(pos, pos, "sliding", window),
                       cfg.logit_softcap)
        out = A.out_proj(layer["attn"], o, dtype)
        if cfg.hybrid_parallel:
            out = (out + ssm_out) * 0.5
        x = x + out
        f, _ = _ffn(layer, rmsnorm(layer["ln2"], x, eps), cfg, moe)
        return x + f, out_extras

    for i, layer in enumerate(params.get("dense_layers", [])):
        x, ex = capture_layer(layer, x, windows[i], False)
        cache["dense_k"] = cache["dense_k"].at[i].set(ex["k"])
        cache["dense_v"] = cache["dense_v"].at[i].set(ex["v"])

    def body(x, xs):
        layer, window = xs
        return capture_layer(layer, shard_act(x), window, cfg.is_moe)

    x, extras = jax.lax.scan(body, x, (params["layers"], windows[n_dense:]))
    for key, val in extras.items():
        cache[key] = val
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = E.lm_head(params["embed"], x[:, -1:], cfg.logit_softcap)[:, 0]
    cache["len"] = jnp.full((B,), L, jnp.int32)
    return logits, cache

"""AdamW optimizer (no optax in this environment — built from scratch).

State dtype is configurable: production large-model configs (llama3-405b
on a single v5e pod) use bf16 first/second moments so optimizer state fits
HBM (DESIGN.md hardware-adaptation note); small-model training uses f32.
The update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                     # peak LR; scaled by the schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"         # bf16 for the memory-tight configs
    factored: bool = False               # Adafactor-style second moment:
    # v for rank>=2 params is stored as row/col means (outer-product
    # reconstruction), shrinking optimizer state from 2x to ~1x params.
    # The production choice for the HBM-edge 405B config (§Perf H1).


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def _factorable(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def _init_v(p, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.state_dtype)
    if cfg.factored and _factorable(p):
        return {"vr": jnp.zeros(p.shape[:-1], dt),          # row means
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
    return jnp.zeros(p.shape, dt)


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(lambda p: _init_v(p, cfg), params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2


def adamw_update(params: Any, grads: Any, state: OptState,
                 cfg: AdamWConfig, lr_scale: jax.Array
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step.  ``lr_scale`` comes from the schedule (f32 scalar)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        g2 = jnp.square(g) + 1e-30
        if isinstance(v, dict):                      # factored second moment
            vr = v["vr"].astype(jnp.float32) * b2 + \
                jnp.mean(g2, axis=-1) * (1 - b2)
            vc = v["vc"].astype(jnp.float32) * b2 + \
                jnp.mean(g2, axis=-2) * (1 - b2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            v32 = vr[..., None] * vc[..., None, :] / \
                jnp.maximum(denom[..., None], 1e-30)
            new_v = {"vr": vr.astype(sdt), "vc": vc.astype(sdt)}
        else:
            v32 = v.astype(jnp.float32) * b2 + g2 * (1 - b2)
            new_v = v32.astype(sdt)
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if _is_matrix(p):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), m32.astype(sdt), new_v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm}

"""Microbatched train step: grad accumulation + AdamW update, jit-ready.

The global batch is split into ``n_micro`` microbatches scanned
sequentially; only one microbatch's activations are live at a time (the
layer scan inside the model is remat'd in groups), which is what lets the
405B config fit a pod — see EXPERIMENTS.md §Perf for the measured effect.

Two memory-critical knobs (both exposed to the dry-run launcher):
* ``accum_dtype`` — the gradient-accumulation buffer dtype.  f32 default;
  bf16 for the HBM-edge configs (405B on one v5e pod).
* ``grad_shardings`` — explicit sharding constraint for the accumulation
  buffer.  Without it XLA's propagation pass chose a data-axis-only layout
  for the scan carry (measured: 101 GiB/device on llama3-405b — see
  EXPERIMENTS.md §Perf iteration 1); constraining it to the parameter
  shardings shards it over `model` too.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import ModelAPI
from repro.training.optim import AdamWConfig, OptState, adamw_update
from repro.training.schedules import Schedule, constant


def make_train_step(api: ModelAPI, opt_cfg: AdamWConfig,
                    schedule: Schedule | None = None,
                    n_micro: int = 1,
                    accum_dtype: str = "float32",
                    grad_shardings: Any = None) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    schedule = schedule or constant()
    adt = jnp.dtype(accum_dtype)

    def micro_loss(params, micro_batch):
        return api.loss(params, micro_batch)

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, grad_shardings)

    def train_step(params, opt_state: OptState, batch: Dict[str, Any]):
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro

        def accum(carry, idx):
            gsum, lsum = carry
            micro = {k: jax.lax.dynamic_slice_in_dim(v, idx * mb, mb, 0)
                     for k, v in batch.items()}
            (loss, _), grads = grad_fn(params, micro)
            grads = _constrain(grads)
            gsum = _constrain(jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), gsum, grads))
            return (gsum, lsum + loss), None

        gzero = _constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, adt), params))
        if n_micro == 1:
            (loss, _), grads = grad_fn(params, batch)
            grads = _constrain(grads)
        else:
            (gsum, lsum), _ = jax.lax.scan(
                accum, (gzero, jnp.zeros((), jnp.float32)),
                jnp.arange(n_micro))
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            loss = lsum / n_micro

        lr_scale = schedule(opt_state.step)
        params, opt_state, info = adamw_update(
            params, grads, opt_state, opt_cfg, lr_scale)
        metrics = {"loss": loss, **info, "lr_scale": lr_scale}
        return params, opt_state, metrics

    return train_step

"""Checkpointing: msgpack-serialised pytrees (no orbax offline).

Arrays are stored as (dtype, shape, raw bytes) keyed by their pytree path;
restore rebuilds into the reference pytree structure (so shardings can be
reapplied by the caller via device_put).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_pytree(tree: Any, path: str) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    blob: Dict[str, Any] = {}
    for keypath, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        blob[_path_str(keypath)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes(),
        }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(blob))
    os.replace(tmp, path)


def restore_pytree(reference: Any, path: str) -> Any:
    with open(path, "rb") as f:
        blob = msgpack.unpackb(f.read())
    flat, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for keypath, ref_leaf in flat:
        rec = blob[_path_str(keypath)]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"])
        arr = arr.reshape(rec["shape"])
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(reference), leaves)


def save_train_state(params: Any, opt_state: Any, step: int,
                     directory: str) -> str:
    path = os.path.join(directory, f"ckpt_{step:08d}.msgpack")
    save_pytree({"params": params, "opt": opt_state._asdict()
                 if hasattr(opt_state, "_asdict") else opt_state}, path)
    return path

from repro.training import checkpoint, optim, schedules, train_step  # noqa: F401

"""Learning-rate schedules.

Includes WSD (warmup-stable-decay) [arXiv:2404.06395] — the schedule the
assigned minicpm-2b was trained with — plus cosine and linear-warmup
baselines.  Each returns an f32 scale in [0, 1] multiplying the peak LR.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def warmup_cosine(warmup: int, total: int, floor: float = 0.1) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd(warmup: int, stable: int, decay: int, floor: float = 0.0
        ) -> Schedule:
    """Warmup-Stable-Decay: linear warmup, flat plateau, then a fast decay
    tail (minicpm uses ~10% of total steps for the decay phase)."""
    def f(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, warmup)
        in_decay = step > warmup + stable
        prog = jnp.clip((step - warmup - stable) / jnp.maximum(1.0, decay),
                        0.0, 1.0)
        tail = 1.0 - (1.0 - floor) * prog
        out = jnp.where(step < warmup, warm,
                        jnp.where(in_decay, tail, 1.0))
        return out
    return f


def constant() -> Schedule:
    return lambda step: jnp.ones((), jnp.float32)

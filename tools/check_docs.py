"""Docs smoke-checker: every command the docs quote must run green, and
every intra-repo link must resolve.

Scans README.md and docs/*.md for fenced ```bash blocks and executes
each line that launches something (``PYTHONPATH=src python ...`` /
``python -m ...``), from the repo root, failing on a non-zero exit.  A
block may be excluded by putting an HTML comment directive with a reason
on the line directly above the fence::

    <!-- docs-check: skip — the tier-1 suite runs in its own CI job -->
    ```bash
    PYTHONPATH=src python -m pytest -q -m "not slow"
    ```

``pip install`` lines are treated as environment setup and skipped (CI
installs the package itself).  Link checking covers every markdown
``[text](target)`` whose target is not an absolute URL or a pure
anchor: the referenced path must exist relative to the file.

Usage::

    python tools/check_docs.py [--list]          # --list: print, don't run
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_RE = re.compile(r"<!--\s*docs-check:\s*skip\b(.*?)-->")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CMD_TIMEOUT = int(os.environ.get("DOCS_CMD_TIMEOUT", "1200"))


def doc_files() -> list:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def extract_commands(path: Path):
    """Yield (lineno, command, skip_reason|None) for each runnable
    command quoted in ``path`` (line-continuations joined)."""
    lines = path.read_text().splitlines()
    in_bash = False
    skip: "str | None" = None
    pending_skip: "str | None" = None
    buf, buf_line = "", 0
    for i, line in enumerate(lines, 1):
        fence = FENCE_RE.match(line.strip())
        if fence and not in_bash:
            if fence.group(1) in ("bash", "sh", "console"):
                in_bash, skip = True, pending_skip
            pending_skip = None
            continue
        if fence and in_bash:
            if buf:      # trailing backslash ran into the closing fence:
                yield buf_line, buf, skip   # run it visibly, never drop it
                buf = ""
            in_bash = False
            continue
        m = SKIP_RE.search(line)
        if m:
            reason = m.group(1).strip()
            if not reason:
                raise SystemExit(f"{path}:{i}: docs-check: skip needs a "
                                 f"stated reason")
            pending_skip = reason
            continue
        if not in_bash:
            if line.strip():        # directive must sit right above the fence
                pending_skip = None
            continue
        stripped = line.strip()
        if not stripped or stripped.startswith("#"):
            continue
        if buf:
            joined = buf + " " + stripped.rstrip("\\").strip()
        else:
            joined = stripped.rstrip("\\").strip()
            buf_line = i
        if stripped.endswith("\\"):
            buf = joined
            continue
        buf = ""
        if joined.startswith("pip "):
            continue                    # environment setup: CI's job
        yield buf_line, joined, skip


def check_links(path: Path) -> list:
    errors = []
    text = path.read_text()
    # strip fenced code (links inside code blocks are not navigation)
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        rel = target.split("#")[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link "
                          f"-> {target}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="print the commands without running them")
    args = ap.parse_args(argv)

    failures = []
    for doc in doc_files():
        failures.extend(check_links(doc))

    n_run = n_skip = 0
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{ROOT / 'src'}" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    for doc in doc_files():
        for lineno, cmd, skip in extract_commands(doc):
            where = f"{doc.relative_to(ROOT)}:{lineno}"
            if skip:
                n_skip += 1
                print(f"[docs-check] SKIP {where}: {cmd}\n"
                      f"             reason: {skip}")
                continue
            n_run += 1
            if args.list:
                print(f"[docs-check] LIST {where}: {cmd}")
                continue
            print(f"[docs-check] RUN  {where}: {cmd}", flush=True)
            t0 = time.time()
            try:
                proc = subprocess.run(cmd, shell=True, cwd=ROOT, env=env,
                                      capture_output=True, text=True,
                                      timeout=CMD_TIMEOUT)
            except subprocess.TimeoutExpired:
                # a hung demo must fail THIS command and keep checking
                # the rest, not abort the whole run with a traceback
                failures.append(f"{where}: timed out after "
                                f"{CMD_TIMEOUT}s: {cmd}")
                continue
            dt = time.time() - t0
            if proc.returncode != 0:
                failures.append(f"{where}: exit {proc.returncode}: {cmd}")
                print(proc.stdout[-4000:])
                print(proc.stderr[-4000:], file=sys.stderr)
            else:
                print(f"[docs-check]      ok ({dt:.1f}s)")
    print(f"[docs-check] {n_run} command(s) "
          f"{'listed' if args.list else 'ran'}, {n_skip} skipped, "
          f"{len(failures)} failure(s)")
    for f in failures:
        print(f"[docs-check] FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Analytic cost model: implementation FLOPs / HBM bytes / collective
bytes per (arch, shape, mesh) — the primary inputs to §Roofline.

Why analytic?  XLA's ``compiled.cost_analysis()`` counts each while-loop
body ONCE (verified experimentally — see EXPERIMENTS.md §Dry-run notes),
so any scanned graph (layer scan, microbatch scan, flash block scans) is
undercounted by the trip count.  We control every matmul in this
framework, so the analytic numbers are exact for compute and principled
estimates for memory/collectives; the HLO numbers are reported alongside
as per-iteration sanity values.

Conventions:
* "impl FLOPs" counts what the kernels actually execute (the blocked
  attention computes full L x L blocks without causal block-skipping —
  that inefficiency is part of the implementation and appears here).
* All quantities are GLOBAL totals; ``per_device`` divides by chips.
* Train counts fwd + bwd (2x fwd) + remat recompute (1x fwd) = 4x fwd.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.config import ModelConfig, ShapeConfig, get_shape

# --- TPU v5e hardware constants (assignment) -------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
BYTES = 2                    # bf16 activations/params on the hot path

TRAIN_FACTOR = 4.0           # fwd + bwd(2x) + remat recompute(1x)
MOE_CAP = 1.25


@dataclasses.dataclass
class Costs:
    flops: float             # global FLOPs for one step
    hbm_bytes: float         # global HBM traffic for one step
    coll_bytes: float        # global collective bytes for one step
    model_flops: float       # 6*N_active*tokens (train) / 2*N_active*T (inf)

    def per_device(self, chips: int) -> "Costs":
        return Costs(self.flops / chips, self.hbm_bytes / chips,
                     self.coll_bytes / chips, self.model_flops / chips)


# ---------------------------------------------------------------------------
# Parameter counts
# ---------------------------------------------------------------------------


def param_count(cfg: ModelConfig, active_only: bool = False) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    if cfg.is_moe:
        fe = cfg.moe_d_ff or cfg.d_ff
        routed = cfg.n_experts_per_tok if active_only else cfg.n_experts
        ffn = 3 * d * fe * routed + 3 * d * fe * cfg.n_shared_experts \
            + d * cfg.n_experts
        dense_ffn = 3 * d * cfg.d_ff * cfg.first_dense_layers
        per_layer = attn + ffn
        total = per_layer * (cfg.n_layers - cfg.first_dense_layers) + \
            (attn + 3 * d * cfg.d_ff) * cfg.first_dense_layers
    elif cfg.arch_type == "ssm":
        dims_inner = cfg.ssm_expand * d
        nh = cfg.ssm_heads or dims_inner // (cfg.ssm_head_dim or 64)
        proj = d * (2 * dims_inner + 2 * cfg.ssm_state + nh) + dims_inner * d
        total = proj * cfg.n_layers
    else:
        ffn = 3 * d * cfg.d_ff if cfg.d_ff else 0
        per_layer = attn + ffn
        if cfg.hybrid_parallel:
            dims_inner = cfg.ssm_expand * d
            nh = dims_inner // (cfg.ssm_head_dim or 64)
            per_layer += d * (2 * dims_inner + 2 * cfg.ssm_state + nh) \
                + dims_inner * d
        total = per_layer * cfg.n_layers
        if cfg.is_encdec:
            total += (attn * 2 + 2 * d * cfg.d_ff) * cfg.encoder_layers
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return float(total + emb)


# ---------------------------------------------------------------------------
# Forward FLOPs per token (full-sequence teacher-forced pass)
# ---------------------------------------------------------------------------


def _attn_ctx(cfg: ModelConfig, L: int) -> float:
    """Average attended context per token as the blocked impl executes it
    (no causal block-skipping -> full L; sliding window -> w + block)."""
    from repro.models.lm import layer_windows
    ws = [int(w) for w in layer_windows(cfg)]
    ctxs = [float(min(L, (w + 512)) if w > 0 else L) for w in ws]
    return sum(ctxs) / len(ctxs)


def fwd_flops_per_token(cfg: ModelConfig, L: int) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    f = 0.0
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.is_moe \
        else 0
    n_dense_ffn = cfg.n_layers - n_moe if not cfg.arch_type == "ssm" else 0

    if cfg.arch_type == "ssm":
        di = cfg.ssm_expand * d
        nh = cfg.ssm_heads or di // (cfg.ssm_head_dim or 64)
        P = cfg.ssm_head_dim or 64
        N = cfg.ssm_state
        Q = cfg.ssm_chunk
        per = 2 * d * (2 * di + 2 * N + nh) + 2 * di * d    # projections
        per += nh * (2 * Q * N + 2 * Q * P + 2 * N * P * 2)  # SSD core
        return per * cfg.n_layers + 2 * d * cfg.vocab_size

    attn_proj = 2 * d * hd * (2 * H + 2 * KV)
    attn_ctx = 4 * _attn_ctx(cfg, L) * H * hd
    per_layer = attn_proj + attn_ctx
    if cfg.hybrid_parallel:
        di = cfg.ssm_expand * d
        nh = di // (cfg.ssm_head_dim or 64)
        P, N, Q = cfg.ssm_head_dim or 64, cfg.ssm_state, cfg.ssm_chunk
        per_layer += 2 * d * (2 * di + 2 * N + nh) + 2 * di * d + \
            nh * (2 * Q * N + 2 * Q * P + 4 * N * P)
    f += per_layer * cfg.n_layers

    if cfg.is_moe:
        fe = cfg.moe_d_ff or cfg.d_ff
        k = cfg.n_experts_per_tok * MOE_CAP
        per_moe = (2 * d * cfg.n_experts          # router
                   + 4 * d * k                    # dispatch/combine einsums
                   + 6 * d * fe * k               # routed experts
                   + 6 * d * fe * cfg.n_shared_experts)
        f += per_moe * n_moe + 6 * d * cfg.d_ff * cfg.first_dense_layers
    else:
        f += 6 * d * cfg.d_ff * n_dense_ffn if cfg.d_ff else 0

    if cfg.is_encdec:
        # encoder runs once per sequence: amortise over decoder tokens
        enc_per_tok = (cfg.encoder_seq / max(1, L)) * cfg.encoder_layers * (
            2 * d * hd * (2 * H + 2 * KV) + 4 * cfg.encoder_seq * H * hd
            + 4 * d * cfg.d_ff)
        # decoder cross-attention: proj + T_enc context
        cross = cfg.n_layers * (2 * d * hd * (2 * H + 2 * KV)
                                + 4 * cfg.encoder_seq * H * hd)
        f += enc_per_tok + cross

    if cfg.attention_mode in ("tconst", "tlin"):
        f += tconst_extra_fwd_per_token(cfg, L)
    return f + 2 * d * cfg.vocab_size                 # lm head


def tconst_extra_fwd_per_token(cfg: ModelConfig, L: int) -> float:
    """Paper Eq. (4) context-path terms, amortised per token, times the
    number of stacked blocks (the gen-path causal/cross terms are already
    covered by the per-layer accounting above, with ctx<=W windows)."""
    tc = cfg.tconst
    d = cfg.d_model
    nb = cfg.tconst_blocks
    # per chunk of W_og tokens: compress + restore 2*D*N*W_oh (N = avg L/2)
    per_chunk = 2 * d * (L / 2) * tc.w_oh * 2 + tc.h * d * tc.w_oh ** 2
    return nb * per_chunk / tc.w_og


# ---------------------------------------------------------------------------
# Step-level costs per shape kind
# ---------------------------------------------------------------------------


def kv_cache_bytes_global(cfg: ModelConfig, B: int, S: int) -> float:
    kvb = cfg.n_kv_heads * cfg.resolved_head_dim * BYTES
    if cfg.attention_mode in ("tconst", "tlin") and cfg.arch_type not in (
            "ssm", "audio"):
        tc = cfg.tconst
        per_block = 2 * B * kvb * ((tc.h + 1) * tc.w_oh + (tc.h + 2) * tc.w_og)
        base = cfg.tconst_blocks * per_block
        if cfg.attention_mode == "tlin":
            base += cfg.tconst_blocks * 2 * B * S * kvb
        return base
    if cfg.arch_type == "ssm":
        di = cfg.ssm_expand * cfg.d_model
        nh = cfg.ssm_heads or di // (cfg.ssm_head_dim or 64)
        st = nh * (cfg.ssm_head_dim or 64) * cfg.ssm_state * 4
        conv = (cfg.ssm_conv - 1) * (di + 2 * cfg.ssm_state) * BYTES
        return cfg.n_layers * B * (st + conv)
    layers = cfg.n_layers
    base = 2.0 * B * S * kvb * layers
    if cfg.hybrid_parallel:
        di = cfg.ssm_expand * cfg.d_model
        nh = di // (cfg.ssm_head_dim or 64)
        base += cfg.n_layers * B * nh * (cfg.ssm_head_dim or 64) * \
            cfg.ssm_state * 4
    if cfg.is_encdec:
        base += 2.0 * B * cfg.encoder_seq * kvb * layers
    return base


def step_costs(cfg: ModelConfig, shape: ShapeConfig, chips: int,
               opt_bytes_per_param: float = 8.0) -> Costs:
    B, L = shape.global_batch, shape.seq_len
    n_params = param_count(cfg)
    n_active = param_count(cfg, active_only=True)
    p_local = n_params * BYTES / chips            # sharded params

    if shape.kind == "train":
        T = B * L
        flops = fwd_flops_per_token(cfg, L) * T * TRAIN_FACTOR
        model_flops = 6.0 * n_active * T
        # HBM: 3 param reads (fwd/bwd/remat) * n_micro-ish amortised as 3,
        # optimizer state r/w, plus activation traffic ~ 12*T*d per layer.
        hbm = 3 * n_params * BYTES + 3 * n_params * opt_bytes_per_param \
            + 12.0 * T * cfg.d_model * BYTES * cfg.n_layers
        # collectives: 2 TP all-reduces/layer fwd, x3 with bwd, of (T, d);
        # + grad reduce (2x params) + 3 FSDP all-gathers of params
        coll = 3 * 2 * cfg.n_layers * T * cfg.d_model * BYTES \
            + 2 * n_params * BYTES + 3 * n_params * BYTES
        if cfg.is_moe:
            coll += 4 * T * cfg.d_model * BYTES * (
                cfg.n_layers - cfg.first_dense_layers)   # all-to-all there+back
        return Costs(flops, hbm, coll, model_flops)

    if shape.kind == "prefill":
        T = B * L
        flops = fwd_flops_per_token(cfg, L) * T
        model_flops = 2.0 * n_active * T
        hbm = n_params * BYTES + 6.0 * T * cfg.d_model * BYTES * cfg.n_layers \
            + kv_cache_bytes_global(cfg, B, L)
        coll = 2 * cfg.n_layers * T * cfg.d_model * BYTES
        if cfg.is_moe:
            coll += 4 * T * cfg.d_model * BYTES * cfg.n_layers
        return Costs(flops, hbm, coll, model_flops)

    # decode: ONE token per sequence against an L-token cache
    flops = decode_flops_per_step(cfg, L) * B
    model_flops = 2.0 * n_active * B
    hbm = n_params * BYTES + decode_cache_read_bytes(cfg, B, L)
    coll = 2 * cfg.n_layers * B * cfg.d_model * BYTES
    if cfg.is_moe:
        coll += 4 * B * cfg.d_model * BYTES * cfg.n_layers
    return Costs(flops, hbm, coll, model_flops)


def decode_flops_per_step(cfg: ModelConfig, S: int) -> float:
    """Per-sequence FLOPs of one serve_step (cache-hit for tconst)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    if cfg.arch_type == "ssm":
        di = cfg.ssm_expand * d
        nh = cfg.ssm_heads or di // (cfg.ssm_head_dim or 64)
        P, N = cfg.ssm_head_dim or 64, cfg.ssm_state
        per = 2 * d * (2 * di + 2 * N + nh) + 2 * di * d + nh * 4 * P * N
        return per * cfg.n_layers + 2 * d * cfg.vocab_size

    if cfg.attention_mode in ("tconst", "tlin") and cfg.arch_type != "audio":
        # paper Eq. (5): (H+1) D W_oh + (H+2) D W_og per block (attention
        # reads), plus all projections/FFNs at 1 token
        tc = cfg.tconst
        nb = cfg.tconst_blocks
        attn_reads = nb * (4 * (tc.h + 1) * H * hd * tc.w_oh +
                           4 * (tc.h + 2) * H * hd * tc.w_og)
        proj = cfg.n_layers * (2 * d * hd * (2 * H + 2 * KV) * 2)  # self+cross
        ffn = cfg.n_layers * 6 * d * cfg.d_ff
        if cfg.attention_mode == "tlin":
            attn_reads += nb * 4 * H * hd * S          # O(N) history reads
        return attn_reads + proj + ffn + 2 * d * cfg.vocab_size

    from repro.models.lm import layer_windows
    ws = [int(w) for w in layer_windows(cfg)]
    ctx = [float(min(S, w) if w > 0 else S) for w in ws]
    attn = sum(4.0 * c * H * hd for c in ctx)
    proj = cfg.n_layers * 2 * d * hd * (2 * H + 2 * KV)
    if cfg.is_moe:
        fe = cfg.moe_d_ff or cfg.d_ff
        ffn = (cfg.n_layers - cfg.first_dense_layers) * (
            6 * d * fe * cfg.n_experts_per_tok
            + 6 * d * fe * cfg.n_shared_experts) \
            + cfg.first_dense_layers * 6 * d * cfg.d_ff
    else:
        ffn = cfg.n_layers * 6 * d * cfg.d_ff if cfg.d_ff else 0
    extra = 0.0
    if cfg.hybrid_parallel:
        di = cfg.ssm_expand * d
        nh = di // (cfg.ssm_head_dim or 64)
        extra = cfg.n_layers * (2 * d * (2 * di + 2 * cfg.ssm_state + nh)
                                + 2 * di * d)
    if cfg.is_encdec:
        extra += cfg.n_layers * (2 * d * hd * (2 * H + 2 * KV)
                                 + 4 * cfg.encoder_seq * H * hd)
    return attn + proj + ffn + extra + 2 * d * cfg.vocab_size


def decode_cache_read_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    """HBM bytes read from the KV cache by one decode step — the paper's
    central quantity: O(1) for tconst, O(S) for the baseline."""
    if cfg.attention_mode in ("tconst", "tlin") and cfg.arch_type not in (
            "ssm", "audio"):
        base = kv_cache_bytes_global(cfg, B, 10**9)   # constant part
        if cfg.attention_mode == "tlin":
            kvb = cfg.n_kv_heads * cfg.resolved_head_dim * BYTES
            base += cfg.tconst_blocks * 2 * B * S * kvb
        return base
    if cfg.arch_type == "ssm":
        return kv_cache_bytes_global(cfg, B, S)
    from repro.models.lm import layer_windows
    kvb = cfg.n_kv_heads * cfg.resolved_head_dim * BYTES
    ws = [int(w) for w in layer_windows(cfg)]
    per_layer = [2.0 * B * (min(S, w) if w > 0 else S) * kvb for w in ws]
    return float(sum(per_layer))


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def roofline(cfg: ModelConfig, shape: ShapeConfig, chips: int = 256,
             hlo: Optional[Dict] = None) -> Dict[str, float]:
    c = step_costs(cfg, shape, chips).per_device(chips)
    t_comp = c.flops / PEAK_FLOPS
    t_mem = c.hbm_bytes / HBM_BW
    t_coll = c.coll_bytes / ICI_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    out = {
        "flops_per_dev": c.flops, "hbm_bytes_per_dev": c.hbm_bytes,
        "coll_bytes_per_dev": c.coll_bytes,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": c.model_flops,
        "useful_flops_ratio": c.model_flops / max(1.0, c.flops),
        "bound_step_s": max(t_comp, t_mem, t_coll),
    }
    if hlo:
        out["hlo_flops_per_dev"] = hlo.get("cost", {}).get("flops", 0.0)
        out["hlo_coll_bytes_per_dev"] = hlo.get(
            "collectives", {}).get("total", 0.0)
    return out

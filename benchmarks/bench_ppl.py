"""Paper Table 1 (structure, reduced scale): validation perplexity parity
between Base / TLinFormer / TConstFormer at matched parameters and
matched observation windows.

No wikitext-103 offline, so the claim validated is the paper's RELATIVE
one (finding 1-2 in §6.3.2): the topological reconstruction does not
lose expressive power — TConst's final PPL is within a small margin of
the baseline's at equal parameter count, on a corpus with long-range
structure."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.data.pipeline import DataConfig, batches
from repro.models.api import build_model
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step

SEQ, BATCH, STEPS, VOCAB = 32, 8, 120, 256


def _train_eval(mode: str, emit) -> float:
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  vocab_size=VOCAB, attention_mode=mode)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(api, opt_cfg,
                                   warmup_cosine(STEPS // 10, STEPS)),
                   donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=VOCAB, seq_len=SEQ, batch_size=BATCH, seed=0)
    for b in batches(dc, steps=STEPS):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"][:, :SEQ])})
    # held-out eval: epoch=99 stream
    loss_fn = jax.jit(lambda p, bt: api.loss(p, bt)[0])
    losses = []
    for b in batches(dc, epoch=99, steps=8):
        losses.append(float(loss_fn(params,
                                    {"tokens": jnp.asarray(
                                        b["tokens"][:, :SEQ])})))
    ce = float(np.mean(losses))
    emit(f"table1_val_ppl/{mode}", math.exp(ce), f"val_ce={ce:.4f}")
    return ce


def run(emit) -> None:
    ce = {m: _train_eval(m, emit) for m in ("full", "tlin", "tconst")}
    emit("table1_ppl_gap_tconst_vs_base",
         math.exp(ce["tconst"]) - math.exp(ce["full"]),
         "PPL delta (paper finding: ~0 at matched windows)")
    emit("table1_ppl_gap_tconst_vs_tlin",
         math.exp(ce["tconst"]) - math.exp(ce["tlin"]),
         "PPL delta (paper finding: tconst matches/outperforms tlin)")

"""Paper §6.3.2 finding 4: robustness to the W_oh/W_total ratio.

The paper's 512-512-X ablation varies the historical-window share across
{0.382, 0.5, 0.618} and finds final PPL stable within a very small range.
Reduced-scale rerun: same three ratios on a W_total=16 observation window
over the synthetic corpus; emits final eval CE per ratio and the spread.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TConstConfig, get_config, reduced
from repro.data.pipeline import DataConfig, batches
from repro.models.api import build_model
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step

SEQ, BATCH, STEPS, VOCAB = 32, 8, 100, 256
W_TOTAL = 16
RATIOS = [0.382, 0.5, 0.618]


def run(emit) -> None:
    ppls = []
    for ratio in RATIOS:
        w_oh = max(2, round(W_TOTAL * ratio / 2) * 2)
        w_og = W_TOTAL - w_oh
        seq = w_og * 4                  # chunk count fixed across ratios
        cfg = reduced(get_config("tconst_41m"), dtype="float32",
                      vocab_size=VOCAB,
                      tconst=TConstConfig(w_oh=w_oh, w_og=w_og, h=2))
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=3e-3)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(api, opt_cfg,
                                       warmup_cosine(10, STEPS)),
                       donate_argnums=(0, 1))
        dc = DataConfig(vocab_size=VOCAB, seq_len=seq, batch_size=BATCH,
                        seed=0)
        for b in batches(dc, steps=STEPS):
            params, opt, _ = step(
                params, opt, {"tokens": jnp.asarray(b["tokens"][:, :seq])})
        loss_fn = jax.jit(lambda p, bt: api.loss(p, bt)[0])
        ces = [float(loss_fn(params,
                             {"tokens": jnp.asarray(b["tokens"][:, :seq])}))
               for b in batches(dc, epoch=77, steps=6)]
        ppl = math.exp(float(np.mean(ces)))
        ppls.append(ppl)
        emit(f"ablation_ratio_ppl/{ratio}", ppl,
             f"W_oh={w_oh} W_og={w_og} (paper 512-512-{ratio})")
    spread = (max(ppls) - min(ppls)) / min(ppls)
    emit("ablation_ratio_ppl_spread", 100.0 * spread,
         "percent; paper finding: stable within a very small range")

"""§Roofline report generator: merges the dry-run JSON (memory_analysis,
HLO cost, parsed collectives) with the analytic cost model into the
per-(arch x shape x mesh) roofline table (markdown + CSV).

Usage:
  PYTHONPATH=src python -m benchmarks.roofline \\
      --dryrun experiments/dryrun_single_pod.json \\
      --out experiments/roofline.md
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

from repro.config import get_shape
from repro.launch.dryrun import plan_config
from benchmarks.costmodel import roofline

HBM_PER_CHIP = 16 * 2**30      # v5e


def build_rows(dryrun: List[Dict], chips: int) -> List[Dict]:
    rows = []
    for rec in dryrun:
        if "error" in rec:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec["error"]})
            continue
        cfg = plan_config(rec["arch"], get_shape(rec["shape"]))
        r = roofline(cfg, get_shape(rec["shape"]), chips=chips, hlo=rec)
        r.update(arch=rec["arch"], shape=rec["shape"],
                 mode=rec["attention_mode"],
                 peak_gib=rec["memory"]["peak_bytes_est"] / 2**30,
                 fits=rec["memory"]["peak_bytes_est"] <= HBM_PER_CHIP,
                 compile_s=rec.get("compile_s"))
        rows.append(r)
    return rows


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mode | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | useful/impl | peak GiB | fits 16G |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                       f"{r['error'][:60]} | | | | | | | |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mode']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {r['peak_gib']:.2f} "
            f"| {'yes' if r['fits'] else 'NO'} |\n")
    return "".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun_single_pod.json")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args(argv)
    with open(args.dryrun) as f:
        dryrun = json.load(f)
    rows = build_rows(dryrun, args.chips)
    md = to_markdown(rows)
    with open(args.out, "w") as f:
        f.write(md)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=2, default=str)
    print(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 8: inference latency, cache-hit/miss split, KV-cache memory,
and speedup ratios vs context length N, for Base / TLinFormer /
TConstFormer at matched (reduced) scale on CPU — plus the cache-layout
sweep (dense / paged / int8 / paged_int8) with the per-step HBM bytes
the LAYOUT-NATIVE kernels touch vs the dense-logical bytes the retired
per-step ``merged()`` densification used to pay.

Validates the paper's qualitative claims at reduced scale:
  (a-c) hit latency: baseline grows with N, TLin grows (gentler),
        TConst is FLAT;
  (g)   KV cache: baseline/TLin O(N), TConst O(1) — reported per
        layout, so paged pools and int8 scales show their true bytes;
  (h-i) hit-step speedup of TConst over Base / TLin grows with N.

Besides the CSV rows, the run writes ``BENCH_inference.json`` (cwd) with
tokens/s, cache bytes per layout, the compacted resync-miss cost, the
prefix-sharing byte accounting, the chunked-admission scenario
(forward tokens / est. prefill FLOPs + warm latency vs unshared-tail
length, shared vs cold vs one-shot, plus the prompt-length-bucketing
compile counts), and the session-tiering scenario (oversubscribed
spill/resume latency + host-tier bytes per layout, and the tconst
admission-cache hit vs cold admission), so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.models.layouts import LayoutSpec
from repro.serving.engine import Engine

N_SWEEP = [256, 512, 1024, 2048]
GEN = 10
OUT_JSON = "BENCH_inference.json"
MESH_SHAPE = (2, 4)                 # (data, model) for the sharded section


def _time_steps(api, params, prompt_len: int, max_len: int) -> Dict:
    eng = Engine(api, params, max_len=max_len)
    batch = {"tokens": jnp.ones((1, prompt_len), jnp.int32)}
    eng.generate(batch, GEN, record_stats=True)       # includes compile
    eng.stats.clear()
    eng.generate(batch, GEN, record_stats=True)       # timed run
    # entries tagged compiled carry one-time jit cost: excluded from the
    # reported numbers (the warm-up run above makes this a no-op here,
    # but the tag keeps the JSON honest if the flow changes)
    hits = [s.seconds for s in eng.stats
            if s.kind == "hit" and not s.compiled]
    misses = [s.seconds for s in eng.stats
              if s.kind == "miss" and not s.compiled]
    prefill = [s.seconds for s in eng.stats if s.kind == "prefill"]
    # chunked decode: one lax.scan dispatch, resync fused on-device —
    # the serving path's zero-host-sync throughput (prefill excluded)
    chunk_s = eng.time_chunked_decode(batch, GEN)
    return {
        "hit_ms": 1e3 * float(np.median(hits)) if hits else float("nan"),
        "miss_ms": 1e3 * float(np.median(misses)) if misses else
                   1e3 * float(prefill[0]),           # baseline: full pass
        "cache_bytes": eng.cache_bytes(1),
        "chunk_tps": (GEN - 1) / chunk_s,
    }


def _layout_sweep(api, params, emit) -> Dict:
    """DecodeAPI v3 (layout-native kernels): cache bytes, chunked
    throughput, and PER-STEP HBM BYTES TOUCHED per layout — the view
    bytes the layout-native step actually reads (assigned pages + table
    for paged, int8+scales for quantized) vs the dense-logical bytes the
    retired per-step ``merged()`` densification used to materialise.
    Also the paged-pool saving for a short-session scenario (slots sized
    for max_len, sessions needing a quarter of it — Fig 8g)."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session

    max_len, slots, short = 512, 4, 128
    out: Dict[str, Dict] = {}
    for kind in ("dense", "paged", "int8", "paged_int8"):
        eng = Engine(api, params, max_len=max_len, layout=kind)
        batch = {"tokens": jnp.ones((1, short), jnp.int32)}
        tps = (GEN - 1) / eng.time_chunked_decode(batch, GEN)
        row = {"cache_bytes": eng.cache_bytes(slots), "chunk_tps": tps}
        state = eng.decode.init_state(slots, max_len)
        row["step_view_bytes"] = state.step_view_bytes()
        row["step_dense_logical_bytes"] = state.dense_logical_bytes()
        if kind in ("paged", "paged_int8"):
            # pool + step bytes when sized for the short sessions actually
            # served: the scheduler assigns only the pages they need, and
            # the kernels walk only those
            page = 64
            pool = slots * (-(-short // page))
            spec = LayoutSpec(kind=kind, page_size=page, pool_pages=pool)
            short_eng = Engine(api, params, max_len=max_len, layout=spec)
            row["cache_bytes_short_pool"] = short_eng.cache_bytes(slots)
            sched = SlotScheduler(build_decode(api.cfg, spec), params,
                                  slots=slots, max_len=max_len,
                                  chunk_size=8)
            sched.submit(Session(np.ones(short - 16, np.int32),
                                 max_new_tokens=8))
            sched.step()
            row["step_view_bytes_short_pool"] = \
                sched.state.step_view_bytes()
        out[kind] = row
        emit(f"layout/{kind}/cache_bytes", row["cache_bytes"],
             f"{slots} slots @ max_len={max_len}")
        emit(f"layout/{kind}/chunk_tps", tps, "tok/s")
        emit(f"layout/{kind}/step_view_bytes", row["step_view_bytes"],
             f"per-step HBM bytes touched; dense-logical="
             f"{row['step_dense_logical_bytes']}")
    emit("layout/paged/cache_bytes_short_pool",
         out["paged"]["cache_bytes_short_pool"],
         f"pool sized for {short}-token sessions; dense pays "
         f"{out['dense']['cache_bytes']}")
    emit("layout/paged/step_view_bytes_short_pool",
         out["paged"]["step_view_bytes_short_pool"],
         "kernel walks only the assigned pages")
    emit("layout/int8_shrink",
         out["dense"]["cache_bytes"] / out["int8"]["cache_bytes"],
         "x smaller KV (~4x for f32)")
    return out


def _shared_prefix_scenario(api, params, kind, emit) -> Dict:
    """Prefix sharing (CoW): S sessions x one common system prompt.
    Reports the physical bytes the page tables reference (a shared page
    is stored — and counted — ONCE) and warm admission latency, with
    sharing on vs off, plus the S=1 baseline.  Acceptance: shared-prefix
    bytes < 1.5x the single-session bytes for S=4, streams identical to
    the no-sharing run."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session

    S, page, gen, chunk = 4, 16, 4, 4
    # prompt 104 = 96-token shared system prefix + 8-token tail: stable
    # prefix (w_og=8 window part excluded) = 96 -> 6 shared pages, and
    # tail+gen+chunk fit one private page -> 7 pages/session
    rng = np.random.RandomState(7)
    common = rng.randint(1, api.cfg.vocab_size, size=96).astype(np.int32)
    prompts = [np.concatenate([common, rng.randint(
        1, api.cfg.vocab_size, size=8).astype(np.int32)]) for _ in range(S)]
    spec = LayoutSpec(kind=kind, page_size=page, pool_pages=28)

    def serve(n_sessions, sharing):
        sched = SlotScheduler(build_decode(api.cfg, spec), params,
                              slots=S, max_len=128, chunk_size=chunk,
                              prefix_sharing=sharing)
        sessions = [sched.submit(Session(p, max_new_tokens=gen))
                    for p in prompts[:n_sessions]]
        sched.admit_pending()
        bytes_admitted = sched.assigned_kv_bytes()
        sched.run()
        warm = [s.seconds for s in sched.admit_stats if not s.compiled]
        return {
            "assigned_kv_bytes": bytes_admitted,
            "admit_warm_ms": 1e3 * float(np.median(warm)) if warm
                             else float("nan"),
            "streams": [s.tokens for s in sessions],
        }

    shared = serve(S, True)
    solo = serve(1, True)
    noshare = serve(S, False)
    ratio = shared["assigned_kv_bytes"] / solo["assigned_kv_bytes"]
    identical = shared["streams"] == noshare["streams"]
    row = {
        "sessions": S,
        "shared_prefix_assigned_kv_bytes": shared["assigned_kv_bytes"],
        "no_sharing_assigned_kv_bytes": noshare["assigned_kv_bytes"],
        "single_session_assigned_kv_bytes": solo["assigned_kv_bytes"],
        "shared_over_single_ratio": ratio,
        "admit_warm_ms_sharing": shared["admit_warm_ms"],
        "admit_warm_ms_no_sharing": noshare["admit_warm_ms"],
        "streams_identical_to_no_sharing": identical,
    }
    emit(f"prefix_sharing/{kind}/assigned_kv_bytes",
         shared["assigned_kv_bytes"],
         f"S={S} shared prompt; no-sharing pays "
         f"{noshare['assigned_kv_bytes']}")
    emit(f"prefix_sharing/{kind}/shared_over_single_ratio", ratio,
         "acceptance: < 1.5 for S=4 (shared prefix stored once)")
    emit(f"prefix_sharing/{kind}/streams_identical", float(identical),
         "1.0 = token-identical to the no-sharing run")
    return row


def _chunked_prefill_scenario(emit) -> Dict:
    """Chunked KV-conditioned admission (PR 5): warm admission latency
    and forward compute (prefill FLOPs) vs the UNSHARED-TAIL length, for
    a prompt whose prefix is resident (prefix sharing) vs a cold prompt,
    on a small dense LM — the family where admission forward compute
    genuinely scales with the tail.  Also the one-shot admission
    baseline.  forward_tokens comes straight from the scheduler's
    admit_stats; FLOPs are estimated as 2 * params * forward_tokens."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session

    cfg = reduced(get_config("smollm_360m"), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    page = chunk = 16
    prefix_len = 64
    spec = LayoutSpec(kind="paged", page_size=page, pool_pages=64)
    rng = np.random.RandomState(11)
    common = rng.randint(1, cfg.vocab_size, size=prefix_len).astype(np.int32)

    def measure(tail: int, sharing: bool, prefill_chunk):
        """Median warm admission over 3 probes (max_new_tokens=1, so a
        probe's slot frees at admission) behind a resident holder."""
        sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                              max_len=256, chunk_size=4,
                              prefix_sharing=sharing,
                              prefill_chunk=prefill_chunk)
        holder = np.concatenate([common, rng.randint(
            1, cfg.vocab_size, size=tail).astype(np.int32)])
        sched.submit(Session(holder, max_new_tokens=32))
        sched.admit_pending()          # prefix now resident + refcounted
        for _ in range(3):
            probe = np.concatenate([common, rng.randint(
                1, cfg.vocab_size, size=tail).astype(np.int32)])
            sched.submit(Session(probe, max_new_tokens=1))
            sched.admit_pending()
        stats = sched.admit_stats[1:]               # drop the holder
        warm = [s for s in stats if not s.compiled] or stats
        return {
            "admit_warm_ms": 1e3 * float(np.median(
                [s.seconds for s in warm])),
            "forward_tokens": warm[-1].forward_tokens,
            "prefill_flops_est": 2.0 * n_params * warm[-1].forward_tokens,
        }

    rows = []
    for tail in (16, 48, 96):
        shared = measure(tail, True, chunk)
        cold = measure(tail, False, chunk)
        oneshot = measure(tail, False, None)
        rows.append({"prefix_len": prefix_len, "tail": tail,
                     "shared": shared, "cold": cold, "oneshot": oneshot})
        emit(f"chunked_prefill/tail={tail}/shared_forward_tokens",
             shared["forward_tokens"],
             f"cold forwards {cold['forward_tokens']} "
             f"(prompt {prefix_len + tail})")
        emit(f"chunked_prefill/tail={tail}/shared_admit_ms",
             shared["admit_warm_ms"],
             f"cold {cold['admit_warm_ms']:.2f}ms, one-shot "
             f"{oneshot['admit_warm_ms']:.2f}ms")
    return {"arch": "smollm_360m(reduced)", "page": page, "chunk": chunk,
            "rows": rows}


def _spill_resume_scenario(api, params, emit) -> Dict:
    """Session tiering (PR 6): oversubscribed serving (4 sessions on 2
    slots, preemptive spill every chunk) per layout — warm RESUME
    latency (one jitted scatter from the host tier) vs the warm COLD
    admission it replaces, the host-tier bytes one spilled session
    costs in each PHYSICAL layout (paged: live pages only; int8: stays
    compressed), and the store occupancy after the run."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session
    from repro.serving.tier_store import TierStore

    gen, L = 8, 24
    rng = np.random.RandomState(13)
    # equal lengths: the one-shot prefill compiles once, so 3 of the 4
    # cold admissions (and all but the first resume) report warm
    prompts = [rng.randint(1, api.cfg.vocab_size, size=L).astype(np.int32)
               for _ in range(4)]
    out: Dict[str, Dict] = {}
    for kind in ("dense", "paged", "int8", "paged_int8"):
        spec = None if kind == "dense" else LayoutSpec(
            kind=kind, page_size=16, pool_pages=32)
        store = TierStore()
        sched = SlotScheduler(build_decode(api.cfg, spec), params,
                              slots=2, max_len=128, chunk_size=4,
                              tier_store=store, preempt_chunks=1)
        for p in prompts:
            sched.submit(Session(p, max_new_tokens=gen))
        sched.run()
        cold = [s.seconds for s in sched.admit_stats
                if s.source == "cold" and not s.compiled]
        resume = [s.seconds for s in sched.admit_stats
                  if s.source == "resume" and not s.compiled]
        sp = sched.spill_stats
        row = {
            "cold_admit_warm_ms": 1e3 * float(np.median(cold)) if cold
                                  else float("nan"),
            "resume_warm_ms": 1e3 * float(np.median(resume)) if resume
                              else float("nan"),
            "spills": sp["spills"],
            "resumes": sp["resumes"],
            "host_bytes_per_spilled_session":
                sp["spilled_bytes"] / max(sp["spills"], 1),
            "store_occupancy_bytes": store.occupancy_bytes,
            "store_entries": len(store),
        }
        out[kind] = row
        emit(f"spill_resume/{kind}/resume_warm_ms", row["resume_warm_ms"],
             f"cold admission {row['cold_admit_warm_ms']:.2f}ms")
        emit(f"spill_resume/{kind}/host_bytes_per_spilled_session",
             row["host_bytes_per_spilled_session"],
             f"{sp['spills']} spills; store holds "
             f"{row['store_occupancy_bytes']} bytes")
    return {"sessions": 4, "slots": 2, "gen": gen, "prompt_len": L,
            "layouts": out}


def _admission_cache_scenario(api, params, emit) -> Dict:
    """The O(1) tconst re-admission: a prompt whose admission snapshot
    is resident in the tier store restores in one scatter (zero forward
    tokens) instead of re-running the O(N) prefill/resync — warm hit vs
    warm cold latency on the paper's own family."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session
    from repro.serving.tier_store import TierStore

    L = 32
    rng = np.random.RandomState(17)
    store = TierStore()

    def admit(prompt):
        sched = SlotScheduler(build_decode(api.cfg), params, slots=1,
                              max_len=128, chunk_size=4, tier_store=store)
        sched.submit(Session(prompt.copy(), max_new_tokens=1))
        sched.admit_pending()
        return sched.admit_stats[-1]

    warmup = rng.randint(1, api.cfg.vocab_size, size=L).astype(np.int32)
    prompt = rng.randint(1, api.cfg.vocab_size, size=L).astype(np.int32)
    admit(warmup)                     # compile the cold prefill
    cold = admit(prompt)              # warm cold: writes the snapshot
    admit(prompt)                     # compile the restore
    hit = admit(prompt)               # warm store hit
    assert hit.source == "store" and hit.forward_tokens == 0
    row = {
        "prompt_len": L,
        "cold_admit_warm_ms": 1e3 * cold.seconds,
        "store_hit_warm_ms": 1e3 * hit.seconds,
        "cold_forward_tokens": cold.forward_tokens,
        "hit_forward_tokens": hit.forward_tokens,
    }
    emit("spill_resume/tconst_admission_cache/store_hit_warm_ms",
         row["store_hit_warm_ms"],
         f"cold {row['cold_admit_warm_ms']:.2f}ms forwarding "
         f"{cold.forward_tokens} tokens; hit forwards 0")
    return row


def _bucketed_admission_scenario(api, params, emit) -> Dict:
    """Prompt-length bucketing: K distinct prompt lengths should produce
    at most bucket-count compile-tagged admissions under the chunked
    (tconst: bucketed fixed-shape) prefill, vs one per length without."""
    from repro.models.api import build_decode
    from repro.serving.scheduler import SlotScheduler
    from repro.serving.session import Session

    lengths = [17, 26, 35, 44]

    def count(prefill_chunk):
        sched = SlotScheduler(build_decode(api.cfg), params, slots=1,
                              max_len=128, chunk_size=4,
                              prefill_chunk=prefill_chunk)
        rng = np.random.RandomState(5)
        for n in lengths:
            sched.submit(Session(rng.randint(
                1, api.cfg.vocab_size, size=n).astype(np.int32),
                max_new_tokens=1))
            sched.admit_pending()
        return sum(1 for s in sched.admit_stats if s.compiled)

    chunked, oneshot = count(16), count(None)
    emit("chunked_prefill/bucketed_compiled_admissions", chunked,
         f"{len(lengths)} distinct prompt lengths; one-shot tags "
         f"{oneshot}")
    return {"lengths": lengths, "chunked_compiled": chunked,
            "oneshot_compiled": oneshot}


def _sharded_decode_scenario(emit, mesh_shape=None) -> Dict:
    """Mesh-native decode (PR 9): the SAME decode path on a (data,
    model) device mesh — per-device vs global KV bytes (head-sharded
    fields split over the model axis), warm chunked-step latency, and
    stream identity against the 1-device run.  Runs on a CPU forced to
    d*m devices via XLA_FLAGS=--xla_force_host_platform_device_count;
    with fewer devices visible the section records WHY it was skipped
    instead of silently vanishing from the JSON."""
    from repro.launch.mesh import make_decode_mesh

    d, m = mesh_shape or MESH_SHAPE
    n = d * m
    if len(jax.devices()) < n:
        reason = (f"needs {n} devices for a {d}x{m} mesh, "
                  f"{len(jax.devices())} visible (set XLA_FLAGS="
                  f"--xla_force_host_platform_device_count={n})")
        emit("sharded_decode/skipped", 1.0, reason)
        return {"skipped": reason, "mesh": f"{d}x{m}"}
    mesh = make_decode_mesh(d, m)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    B, L, max_len = 2, 32, 128
    rows: Dict[str, Dict] = {}
    scenarios = {
        "tconst/dense": (reduced(get_config("tconst_41m"),
                                 dtype="float32"), None),
        # tlin's O(N) history KV actually lives in pool pages — the row
        # that proves the paged pool + page tables run sharded
        "tlin/paged": (reduced(get_config("tconst_41m"), dtype="float32",
                               attention_mode="tlin"),
                       LayoutSpec(kind="paged", page_size=16,
                                  pool_pages=2 * B * (max_len // 16))),
    }
    for name, (cfg, spec) in scenarios.items():
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((B, L), jnp.int32)}
        ref_eng = Engine(api, params, max_len=max_len, layout=spec)
        ref = ref_eng.generate(batch, GEN)
        eng = Engine(api, jax.device_put(params, repl), max_len=max_len,
                     layout=spec, mesh=mesh)
        out = eng.generate(batch, GEN)
        identical = bool(np.array_equal(ref, out))
        state = eng.decode.init_state(B, max_len)
        glob, per_dev = state.kv_bytes(), state.per_device_kv_bytes()
        row = {
            "stream_identical_to_1device": identical,
            "kv_bytes_global": glob,
            "kv_bytes_per_device": per_dev,
            "global_over_per_device": glob / max(per_dev, 1),
            "chunk_step_ms":
                1e3 * eng.time_chunked_decode(batch, GEN) / (GEN - 1),
            "chunk_step_ms_1device":
                1e3 * ref_eng.time_chunked_decode(batch, GEN) / (GEN - 1),
        }
        rows[name] = row
        emit(f"sharded_decode/{name}/stream_identical", float(identical),
             f"mesh {d}x{m} vs 1 device (greedy)")
        emit(f"sharded_decode/{name}/kv_bytes_per_device", per_dev,
             f"global {glob} ({row['global_over_per_device']:.2f}x; "
             f"model axis = {m})")
    return {"mesh": f"{d}x{m}", "devices": n, "batch": B,
            "prompt_len": L, "gen": GEN, "rows": rows}


def _spec_decode_scenario(emit, gen: int = 48) -> Dict:
    """Speculative decoding (PR 10): per-slot tokens/s of the n-gram
    self-drafter on a REPEAT-HEAVY prompt (a 16-token motif tiled to
    64), per family, against the sequential STREAMING baseline — one
    decode dispatch per token, the interactive serving regime
    speculation actually targets.  A verify round is also one dispatch
    (k+1 positions, fixed shape), so the speedup is committed-tokens-
    per-dispatch x dispatch-cost ratio; acceptance is verify-exact, so
    ``stream_identical`` is asserted (and schema-gated), never assumed.
    The zero-host-sync chunked scan is reported alongside for scale —
    it amortizes dispatch overhead across the whole chunk but cannot
    stream a token until the chunk retires."""
    from repro.config import TConstConfig
    k = 4
    rows: Dict[str, Dict] = {}
    # tconst: widen the generation window to 64 (the reduced default of 8
    # makes the verify budget cap every round at w_og - gen_len <= 8
    # tokens and a resync fires every 8 tokens — that measures the
    # window cap, not the drafter; budget capping has its own tests)
    base = get_config("tconst_41m")
    fams = (
        ("tconst", "tconst_41m(reduced,w_og=64)",
         reduced(base, dtype="float32",
                 tconst=TConstConfig(w_oh=8, w_og=64, h=base.tconst.h))),
        ("lm", "smollm_360m(reduced)",
         reduced(get_config("smollm_360m"), dtype="float32")),
    )
    for name, arch_label, cfg in fams:
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(23)
        motif = rng.integers(1, cfg.vocab_size, (16,))
        prompt = np.tile(motif, 6)[:64].astype(np.int32)[None]
        batch = {"tokens": prompt}
        max_len = 64 + gen + 2 * k + 8

        # sequential streaming baseline: one dispatch per token (warm)
        eng = Engine(api, params, max_len=max_len)
        ref = eng.generate(dict(batch), gen, record_stats=True)
        eng.stats.clear()
        ref2 = eng.generate(dict(batch), gen, record_stats=True)
        assert np.array_equal(ref, ref2)
        seq = [s.seconds for s in eng.stats
               if s.kind in ("hit", "miss") and not s.compiled]
        seq_tps = (gen - 1) / sum(seq)
        chunk_tps = (gen - 1) / eng.time_chunked_decode(dict(batch), gen)

        # speculative: one verify dispatch per round, warm timing
        spec_eng = Engine(api, params, max_len=max_len)
        out = spec_eng.generate_speculative(dict(batch), gen, k=k)
        identical = bool(np.array_equal(ref, out))
        spec_eng.stats.clear()
        out2 = spec_eng.generate_speculative(dict(batch), gen, k=k)
        identical = identical and bool(np.array_equal(ref, out2))
        warm = [s for s in spec_eng.stats
                if s.kind == "spec_chunk" and not s.compiled]
        spec_tps = (sum(s.tokens for s in warm)
                    / sum(s.seconds for s in warm))
        rounds = spec_eng.spec_rounds
        row = {
            "arch": arch_label, "drafter": "ngram", "k": k,
            "gen": gen, "prompt_len": 64, "motif_len": 16,
            "stream_identical": identical,
            "sequential_tps": seq_tps,
            "spec_tps": spec_tps,
            "speedup_vs_sequential": spec_tps / seq_tps,
            "chunked_scan_tps": chunk_tps,
            "rounds": rounds,
            "tokens_per_round": (gen - 1) / rounds,
        }
        rows[name] = row
        emit(f"spec_decode/{name}/speedup_vs_sequential",
             row["speedup_vs_sequential"],
             f"spec {spec_tps:.0f} tok/s vs sequential {seq_tps:.0f} "
             f"({row['tokens_per_round']:.2f} tokens/round, k={k})")
        emit(f"spec_decode/{name}/stream_identical", float(identical),
             "1.0 = verify-exact: token-identical to plain generate")
    return {"drafter": "ngram", "k": k, "rows": rows}


def validate_payload(payload: Dict, smoke: bool = False) -> List[str]:
    """Structural check of a ``BENCH_inference.json`` payload (CI gate
    for the sharded section; full payloads also need the fig8 blocks).
    Returns a list of problems (empty = valid)."""
    errs: List[str] = []

    def need(cond: bool, msg: str) -> None:
        if not cond:
            errs.append(msg)

    sharded = payload.get("sharded_decode")
    need(isinstance(sharded, dict), "missing sharded_decode")
    if isinstance(sharded, dict):
        if "skipped" in sharded:
            need(isinstance(sharded["skipped"], str) and sharded["skipped"],
                 "sharded_decode.skipped must say why")
        else:
            rows = sharded.get("rows")
            need(isinstance(rows, dict) and rows, "sharded_decode: no rows")
            for name, row in (rows or {}).items():
                where = f"sharded_decode/{name}"
                need(row.get("stream_identical_to_1device") is True,
                     f"{where}: stream differs from the 1-device run")
                for k in ("kv_bytes_global", "kv_bytes_per_device",
                          "global_over_per_device", "chunk_step_ms"):
                    need(isinstance(row.get(k), (int, float)),
                         f"{where}: missing {k}")
                if "kv_bytes_per_device" in row:
                    need(row["kv_bytes_per_device"] <=
                         row.get("kv_bytes_global", 0),
                         f"{where}: per-device bytes exceed global")
    full = not smoke and not payload.get("meta", {}).get("smoke")
    spec = payload.get("spec_decode")
    need(isinstance(spec, dict), "missing spec_decode")
    if isinstance(spec, dict):
        rows = spec.get("rows")
        need(isinstance(rows, dict) and rows, "spec_decode: no rows")
        for name, row in (rows or {}).items():
            where = f"spec_decode/{name}"
            need(row.get("stream_identical") is True,
                 f"{where}: speculative stream differs from plain "
                 f"generate (verify-exactness broken)")
            for k in ("sequential_tps", "spec_tps",
                      "speedup_vs_sequential", "tokens_per_round"):
                need(isinstance(row.get(k), (int, float)),
                     f"{where}: missing {k}")
            if full and row.get("drafter") == "ngram":
                # perf floor only for full (artifact) runs — smoke/CI
                # runners gate exactness, not wall-clock
                need(row.get("speedup_vs_sequential", 0.0) >= 1.3,
                     f"{where}: ngram speedup "
                     f"{row.get('speedup_vs_sequential')} < 1.3x on the "
                     f"repeat-heavy workload")
    if full:
        for k in ("n_sweep", "variants", "layouts", "spill_resume",
                  "derived"):
            need(k in payload, f"missing {k}")
    return errs


def run(emit) -> None:
    variants = {
        "base": reduced(get_config("tconst_41m"), dtype="float32",
                        attention_mode="full"),
        "tlin": reduced(get_config("tconst_41m"), dtype="float32",
                        attention_mode="tlin"),
        "tconst": reduced(get_config("tconst_41m"), dtype="float32"),
    }
    results: Dict[str, List[Dict]] = {}
    layouts: Dict[str, Dict] = {}
    prefix_sharing: Dict[str, Dict] = {}
    bucketed: Dict[str, Dict] = {}
    spill_resume: Dict[str, Dict] = {}
    for name, cfg in variants.items():
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        rows = []
        for n in N_SWEEP:
            r = _time_steps(api, params, n, n + GEN + 64)
            rows.append(r)
            emit(f"fig8_latency/{name}/N={n}/hit", r["hit_ms"] * 1e3,
                 f"miss_ms={r['miss_ms']:.1f}")
            emit(f"fig8_memory/{name}/N={n}", r["cache_bytes"],
                 "kv_cache_bytes")
            emit(f"chunked_decode_tps/{name}/N={n}", r["chunk_tps"],
                 "tok/s, single-dispatch chunked decode")
        results[name] = rows
        if name in ("tlin", "tconst"):
            layouts[name] = _layout_sweep(api, params,
                                          lambda k, v, d="": emit(
                                              f"{name}/{k}", v, d))
        if name == "tlin":
            # prefix sharing needs fields that actually live in pages:
            # tlin's O(N) history KV (pure-tconst KV is already O(1))
            prefix_sharing = {
                kind: _shared_prefix_scenario(api, params, kind, emit)
                for kind in ("paged", "paged_int8")}
            # session tiering on the family whose KV actually pages:
            # spill/resume latency + host-tier bytes per layout
            spill_resume = _spill_resume_scenario(api, params, emit)
        if name == "tconst":
            # bucketing headline for the paper's own family: admission
            # collapses to ONE fixed-shape dispatch (resync is already
            # max_len-shaped; the window pass pads to W_og)
            bucketed[name] = _bucketed_admission_scenario(api, params,
                                                          emit)
            spill_resume["tconst_admission_cache"] = \
                _admission_cache_scenario(api, params, emit)
    chunked_prefill = _chunked_prefill_scenario(emit)
    chunked_prefill["bucketed_admissions"] = bucketed

    # derived paper claims ---------------------------------------------------
    tc = results["tconst"]
    flat = tc[-1]["hit_ms"] / max(tc[0]["hit_ms"], 1e-9)
    emit("fig8c_tconst_hit_flatness", flat,
         "hit(Nmax)/hit(Nmin); ~1.0 = constant-time (paper: horizontal)")
    cache_ratio = tc[-1]["cache_bytes"] / tc[0]["cache_bytes"]
    emit("fig8g_tconst_cache_O1", cache_ratio, "must be 1.0")
    for other in ("base", "tlin"):
        o = results[other]
        grow = o[-1]["cache_bytes"] / o[0]["cache_bytes"]
        emit(f"fig8g_{other}_cache_growth", grow, "grows with N")
        sp_small = o[0]["hit_ms"] / tc[0]["hit_ms"]
        sp_big = o[-1]["hit_ms"] / tc[-1]["hit_ms"]
        emit(f"fig8hi_speedup_vs_{other}/N={N_SWEEP[0]}", sp_small, "x")
        emit(f"fig8hi_speedup_vs_{other}/N={N_SWEEP[-1]}", sp_big,
             "x (paper: grows with N)")

    payload = {
        "n_sweep": N_SWEEP,
        "gen": GEN,
        # per-variant rows: hit/miss latency (miss = compacted row-wise
        # resync cost for tconst/tlin), cache bytes, chunked tok/s
        "variants": results,
        "layouts": layouts,
        # S sessions x one system prompt: shared prefix pages stored
        # once (assigned_kv_bytes), streams identical, warm admission
        # latency with/without sharing (compile-tagged entries excluded)
        "prefix_sharing": prefix_sharing,
        # chunked KV-conditioned admission: forward tokens / est. FLOPs
        # and warm latency vs unshared-tail length (shared vs cold vs
        # one-shot), plus the prompt-length-bucketing compile counts
        "chunked_prefill": chunked_prefill,
        # session tiering: oversubscribed spill/resume latency + host-
        # tier bytes per layout, and the tconst admission-cache hit
        # (O(1) re-admission: zero forward tokens) vs cold admission
        "spill_resume": spill_resume,
        # mesh-native decode: per-device vs global KV bytes, step
        # latency, and stream identity vs the 1-device run on a forced
        # multi-device mesh (or a "skipped" reason on 1 device)
        "sharded_decode": _sharded_decode_scenario(emit),
        # speculative decoding: n-gram drafter tokens/s per slot vs the
        # sequential streaming baseline on the repeat-heavy workload,
        # with the verify-exact stream-identity bit (schema-gated)
        "spec_decode": _spec_decode_scenario(emit),
        "derived": {
            "tconst_hit_flatness": flat,
            "tconst_cache_O1_ratio": cache_ratio,
        },
    }
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("bench_inference_json", 0.0, f"written to {OUT_JSON}")


def main(argv=None) -> int:
    """CLI mirror of ``benchmarks.run``'s entry point, plus the CI modes:
    ``--smoke --mesh 2x4`` runs JUST the sharded_decode section (the
    fig8 sweeps are minutes of CPU) and schema-checks it; ``--check``
    validates an existing payload file."""
    global MESH_SHAPE
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="sharded_decode section only (CI)")
    ap.add_argument("--mesh", default="x".join(map(str, MESH_SHAPE)),
                    help="DxM mesh for the sharded section "
                         f"(default {MESH_SHAPE[0]}x{MESH_SHAPE[1]})")
    ap.add_argument("--out", default=OUT_JSON,
                    help=f"output path (default {OUT_JSON})")
    ap.add_argument("--check", metavar="JSON",
                    help="validate an existing payload and exit")
    ap.add_argument("--section", choices=["spec_decode"],
                    help="run ONE section and merge it into --out "
                         "(existing payload kept if the file parses); "
                         "the CI spec-decode lane uses this")
    args = ap.parse_args(argv)
    if args.check:
        with open(args.check) as f:
            errs = validate_payload(json.load(f))
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        print(f"{args.check}: " + ("INVALID" if errs else "ok"))
        return 1 if errs else 0

    def emit(name, value, derived=""):
        print(f"{name},{value},{derived}", flush=True)

    try:
        d, m = (int(s) for s in args.mesh.lower().split("x"))
    except ValueError:
        ap.error(f"--mesh {args.mesh!r} must be DxM, e.g. 2x4")
    if args.section:
        try:
            with open(args.out) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            payload = {}
        # a fresh or partial file is a smoke artifact; merging into an
        # existing full payload must NOT demote it to smoke (the --check
        # perf floor would silently stop applying) — and a full payload
        # gets the full-length scenario
        smoke_flag = bool(payload.get("meta", {}).get("smoke", not payload))
        payload.setdefault("meta", {})["smoke"] = smoke_flag
        payload["spec_decode"] = _spec_decode_scenario(
            emit, gen=24 if smoke_flag else 48)
        if "sharded_decode" not in payload:
            payload["sharded_decode"] = {
                "skipped": "spec_decode section run only", "mesh": "-"}
    elif args.smoke:
        payload = {"meta": {"smoke": True, "mesh": args.mesh},
                   "sharded_decode":
                       _sharded_decode_scenario(emit, (d, m)),
                   "spec_decode": _spec_decode_scenario(emit, gen=24)}
    else:
        MESH_SHAPE = (d, m)
        payload = None
        run(emit)
        with open(OUT_JSON) as f:
            payload = json.load(f)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    errs = validate_payload(payload, smoke=args.smoke)
    if errs:
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        return 1
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig8,eq,fig6,table1,serving]

Prints ``name,us_per_call,derived`` CSV rows (plus derived claim checks).
Roofline terms come from the dry-run artifacts via ``benchmarks.roofline``
(separate entry point — it needs the 512-device XLA_FLAGS env).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig8,eq,fig6,table1,ablation,serving")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def emit(name: str, value: float, derived: str = "") -> None:
        print(f"{name},{value:.6g},{derived}", flush=True)

    print("name,us_per_call,derived")
    suites = [
        ("eq", "benchmarks.bench_complexity"),
        ("fig6", "benchmarks.bench_training"),
        ("fig8", "benchmarks.bench_inference"),
        ("table1", "benchmarks.bench_ppl"),
        ("ablation", "benchmarks.bench_ablation"),
        ("serving", "benchmarks.bench_serving"),
    ]
    for key, modname in suites:
        if only is not None and key not in only:
            continue
        t0 = time.time()
        print(f"# --- {modname} ---", flush=True)
        mod = __import__(modname, fromlist=["run"])
        mod.run(emit)
        print(f"# {modname} done in {time.time() - t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())

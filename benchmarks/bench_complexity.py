"""Paper Eq. (4)/(5) validation via COMPILED FLOP counts (rigorous, not
wall-clock): lower+compile the decode step at several context lengths and
read XLA's per-step flops.

* TConst cache-hit step: flops must be INDEPENDENT of N  (Eq. 5)
* TConst resync (cache miss): flops must be LINEAR in N  (Eq. 4)
* Baseline decode step: flops grow linearly in N (attention reads)

The layer scan's trip count is constant across N, so XLA's
count-body-once behaviour cancels in these comparisons.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model

N_SWEEP = [512, 1024, 2048, 4096]


def _compiled_flops(fn, *args) -> float:
    c = jax.jit(fn).lower(*args).compile()
    return float(c.cost_analysis().get("flops", 0.0))


def run(emit) -> None:
    for mode in ("full", "tconst"):
        cfg = reduced(get_config("tconst_41m"), dtype="float32",
                      attention_mode=mode)
        api = build_model(cfg)
        params_s = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        hits, misses = [], []
        for n in N_SWEEP:
            cache_s = api.cache_specs(1, n)
            tok_s = jax.ShapeDtypeStruct((1,), jnp.int32)
            f_hit = _compiled_flops(
                lambda p, c, t: api.decode_step(p, c, t),
                params_s, cache_s, tok_s)
            hits.append(f_hit)
            emit(f"eq5_decode_flops/{mode}/N={n}", f_hit, "per-step flops")
            if mode == "tconst":
                f_miss = _compiled_flops(
                    lambda p, c: api.resync(p, c), params_s, cache_s)
                misses.append(f_miss)
                emit(f"eq4_resync_flops/N={n}", f_miss, "per-miss flops")
        ratio = hits[-1] / hits[0]
        emit(f"decode_flops_scaling/{mode}", ratio,
             f"flops(N={N_SWEEP[-1]})/flops(N={N_SWEEP[0]}); "
             f"{'O(1) expected ~1.0' if mode == 'tconst' else 'O(N) grows'}")
        if misses:
            lin = (misses[-1] / misses[0]) / (N_SWEEP[-1] / N_SWEEP[0])
            emit("resync_flops_linearity", lin,
                 "ratio/(N ratio); ~1.0 = strictly linear (Eq. 4)")

"""Paper Fig. 6: training-time overhead of the chunked TConst/TLin
forward vs the baseline at matched scale (reduced models, CPU steps/s).
The paper reports ~42% overhead at 1K; the chunked scan scheduling cost
is the same mechanism at reduced scale."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

SEQ = 64
BATCH = 4
STEPS = 8


def run(emit) -> None:
    from repro.config import TConstConfig
    base_time = None
    for mode in ("full", "tlin", "tconst"):
        # paper naming: "64-64-0.5" — W_total = seq, W_oh/W_total = 0.5
        # (the 1K-1K-0.5 configuration of §6.3.1, reduced)
        cfg = reduced(get_config("tconst_41m"), dtype="float32",
                      attention_mode=mode,
                      tconst=TConstConfig(w_oh=SEQ // 2, w_og=SEQ // 2, h=2))
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(api, opt_cfg, n_micro=1),
                       donate_argnums=(0, 1))
        batch = {"tokens": jnp.ones((BATCH, SEQ), jnp.int32)}
        params, opt, _ = jax.block_until_ready(step(params, opt, batch))
        t0 = time.perf_counter()
        for _ in range(STEPS):
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / STEPS
        emit(f"fig6_train_step_s/{mode}", dt * 1e6,
             f"{BATCH * SEQ / dt:.0f} tok/s")
        if mode == "full":
            base_time = dt
        else:
            emit(f"fig6_train_overhead/{mode}",
                 100.0 * (dt / base_time - 1.0),
                 "percent vs baseline, CPU wall-clock at toy scale "
                 "(dispatch-bound; see analytic number below)")

    # Analytic FLOP overhead at the PAPER's actual scale (41M, seq 1K,
    # 1K-1K-0.5 windows) — the architectural cost of the chunked context
    # path, free of CPU dispatch noise.  Paper measured ~42% wall-clock.
    from benchmarks.costmodel import fwd_flops_per_token
    from repro.config import TConstConfig as TCC
    paper = get_config("tconst_41m").replace(
        tconst=TCC(w_oh=512, w_og=512, h=2))
    base = paper.replace(attention_mode="full")
    f_base = fwd_flops_per_token(base, 1024)
    f_tc = fwd_flops_per_token(paper, 1024)
    emit("fig6_train_flop_overhead_paper_scale",
         100.0 * (f_tc / f_base - 1.0),
         "percent extra fwd FLOPs, 41M @ 1K, 1K-1K-0.5 (paper: ~42% time)")

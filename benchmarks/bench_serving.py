"""SLO-aware serving bench: workload generator x scheduling policies.

Drives seeded traffic traces (``repro.serving.workload``) through the
slot scheduler under BOTH shipped policies (``fifo`` baseline,
``slo`` deadline/cost-aware) per {arch x layout}, collects per-session
telemetry (``repro.serving.metrics``), and writes ``BENCH_serving.json``
(cwd) so the serving trajectory is tracked per PR alongside
``BENCH_inference.json``.

Per {scenario x arch/layout x policy} the JSON records p50/p99 TTFT and
inter-token latency (scheduler-chunk units — deterministic across
hosts — plus compile-excluded wall seconds), queue wait, SLO
attainment, spill/resume counts and store hits.  Two gates ride along:

* **stream identity** — every session's token stream (temperature 0.7,
  per-session sampling chains) must be identical across policies; the
  bench raises otherwise.  A policy is a *scheduling* decision, never a
  *sampling* one.
* **SLO win** — in the oversubscribed bursty scenario the deadline/
  cost-aware policy must beat FIFO on TTFT SLO attainment (it trades
  best-effort p99 TTFT for deadline hits — both visible in the JSON).

Usage::

  PYTHONPATH=src python -m benchmarks.bench_serving            # full
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke    # CI
  PYTHONPATH=src python -m benchmarks.bench_serving --check BENCH_serving.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_decode, build_model
from repro.models.layouts import LayoutSpec
from repro.serving.metrics import ServingTelemetry
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session
from repro.serving.tier_store import TierStore
from repro.serving.workload import WorkloadSpec, generate_workload

OUT_JSON = "BENCH_serving.json"
SEED = 42
POLICIES = ("fifo", "slo")
MAX_STEPS = 20_000                  # runaway guard per run

# arch x layout rows: the paper family (tconst: O(1) KV, spills are
# near-free, repeats re-admit O(1) from the store) vs a dense LM under
# a paged pool sized well below peak demand (page pressure + expensive
# spills — the regime cost-aware victim selection exists for)
ARCHS: Dict[str, Dict] = {
    "tconst/dense": {
        "config": "tconst_41m",
        "layout": None,
        "scheduler": dict(slots=3, max_len=104, chunk_size=4,
                          preempt_chunks=2, prefill_chunk=16),
    },
    "lm/paged": {
        "config": "smollm_360m",
        "layout": dict(kind="paged", page_size=8, pool_pages=30),
        "scheduler": dict(slots=3, max_len=104, chunk_size=4,
                          preempt_chunks=2, prefill_chunk=16,
                          prefix_sharing=True),
    },
}


def _scenarios(vocab: int, n_sessions: int) -> Dict[str, WorkloadSpec]:
    """The two committed traffic shapes.  ``steady_poisson`` is a
    moderately loaded open-loop trace with a shared-prefix population;
    ``bursty_oversubscribed`` drops whole bursts on a 3-slot scheduler
    with tight TTFT deadlines on a 40% slice — the scenario the SLO
    policy must win."""
    return {
        "steady_poisson": WorkloadSpec(
            n_sessions=n_sessions, vocab=vocab, arrival="poisson",
            rate=0.35, temperature=0.7,
            prompt_mix=((0.7, 8, 24), (0.3, 32, 56)),
            output_mix=((0.8, 8, 16), (0.2, 20, 32)),
            shared_frac=0.3, n_prefixes=2, prefix_len=16,
            repeat_frac=0.2, slo_frac=0.5, slo_ttft_chunks=8),
        "bursty_oversubscribed": WorkloadSpec(
            n_sessions=n_sessions, vocab=vocab, arrival="bursty",
            burst_size=14, burst_every=30.0, temperature=0.7,
            prompt_mix=((0.7, 8, 24), (0.3, 32, 56)),
            output_mix=((0.6, 12, 20), (0.4, 24, 40)),
            repeat_frac=0.25, slo_frac=0.4, slo_ttft_chunks=5),
    }


def _drive(sched: SlotScheduler, arrivals) -> None:
    """Clocked open-loop replay: submit each arrival once the scheduler
    clock reaches its chunk, step until drained."""
    i = 0
    while i < len(arrivals) or sched.pending or sched.active.any():
        while i < len(arrivals) and arrivals[i].at_chunk <= sched.clock:
            sched.submit(arrivals[i].session)
            i += 1
        sched.step()
        if sched.clock > MAX_STEPS:
            raise RuntimeError("bench run exceeded the step guard — "
                               "the scheduler is not draining")


def _run_once(arch: Dict, api, params, spec: WorkloadSpec,
              policy: str) -> Tuple[List[Tuple[int, ...]], Dict]:
    layout = arch["layout"] and LayoutSpec(**arch["layout"])
    decode = build_decode(api.cfg, layout)
    telemetry = ServingTelemetry()
    kw = dict(arch["scheduler"])
    sched = SlotScheduler(decode, params, tier_store=TierStore(),
                          policy=policy, telemetry=telemetry, **kw)
    arrivals = generate_workload(
        spec, SEED, max_prompt_len=kw["max_len"] - 48)
    _drive(sched, arrivals)
    streams = [tuple(a.session.tokens) for a in arrivals]
    summary = telemetry.summary()
    summary["store"] = {
        "spills": sched.spill_stats["spills"],
        "resumes": sched.spill_stats["resumes"],
        "admit_store_hits": sched.spill_stats["admit_store_hits"],
        "pages_readopted": sched.spill_stats["pages_readopted"],
    }
    return streams, summary


def _bench(smoke: bool, emit) -> Dict:
    n_sessions = 12 if smoke else 48
    archs = {k: v for k, v in ARCHS.items()
             if not smoke or k == "tconst/dense"}
    payload: Dict = {
        "meta": {"smoke": smoke, "seed": SEED, "policies": list(POLICIES),
                 "n_sessions_per_run": n_sessions},
        "scenarios": {},
        "derived": {},
    }
    wins: Dict[str, bool] = {}
    for arch_name, arch in archs.items():
        cfg = reduced(get_config(arch["config"]), dtype="float32")
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        for scen_name, spec in _scenarios(cfg.vocab_size,
                                          n_sessions).items():
            scen = payload["scenarios"].setdefault(
                scen_name, {"spec": dataclasses.asdict(spec),
                            "runs": {}})
            run_row: Dict = {}
            streams: Dict[str, List] = {}
            for policy in POLICIES:
                streams[policy], run_row[policy] = _run_once(
                    arch, api, params, spec, policy)
                s = run_row[policy]
                emit(f"serving/{scen_name}/{arch_name}/{policy}"
                     f"/p99_ttft_chunks", s["ttft_chunks"]["p99"] or 0.0,
                     f"ttft_slo_attainment="
                     f"{s['slo']['ttft_attainment']}")
            identical = streams["fifo"] == streams["slo"]
            run_row["streams_identical_across_policies"] = identical
            if not identical:
                raise AssertionError(
                    f"{scen_name}/{arch_name}: token streams differ "
                    f"across scheduling policies — the policy seam "
                    f"leaked into sampling")
            att = {p: run_row[p]["slo"]["ttft_attainment"]
                   for p in POLICIES}
            if scen_name == "bursty_oversubscribed":
                wins[arch_name] = (att["slo"] or 0) > (att["fifo"] or 0)
            scen["runs"][arch_name] = run_row
    payload["derived"] = {
        "slo_beats_fifo_ttft_attainment_oversubscribed": wins,
        "any_oversubscribed_win": any(wins.values()),
        "all_streams_identical": True,       # raised above otherwise
    }
    if not smoke and not payload["derived"]["any_oversubscribed_win"]:
        raise AssertionError(
            "the deadline/cost-aware policy did not beat FIFO on TTFT "
            "SLO attainment in the oversubscribed scenario")
    return payload


# ---------------------------------------------------------------------------
# schema validation (CI gate for the committed artifact)
# ---------------------------------------------------------------------------

_PCTL_KEYS = {"p50", "p99"}
_RUN_KEYS = {"sessions", "finished", "tokens_out", "ttft_chunks",
             "ttft_seconds_warm", "ttft_compile_excluded", "itl_chunks",
             "queue_wait_chunks", "slo", "spills", "resumes",
             "pool_occupancy_mean", "store"}


def validate_payload(payload: Dict) -> List[str]:
    """Structural check of a ``BENCH_serving.json`` payload; returns a
    list of problems (empty = valid)."""
    errs: List[str] = []

    def need(cond: bool, msg: str) -> None:
        if not cond:
            errs.append(msg)

    need(isinstance(payload.get("meta"), dict), "missing meta")
    need(isinstance(payload.get("derived"), dict), "missing derived")
    scenarios = payload.get("scenarios")
    need(isinstance(scenarios, dict) and scenarios, "missing scenarios")
    for scen_name, scen in (scenarios or {}).items():
        need(isinstance(scen.get("spec"), dict),
             f"{scen_name}: missing spec")
        runs = scen.get("runs")
        need(isinstance(runs, dict) and runs, f"{scen_name}: no runs")
        for arch_name, row in (runs or {}).items():
            where = f"{scen_name}/{arch_name}"
            need(row.get("streams_identical_across_policies") is True,
                 f"{where}: streams not identical across policies")
            for policy in POLICIES:
                run = row.get(policy)
                if not isinstance(run, dict):
                    errs.append(f"{where}: missing {policy} run")
                    continue
                missing = _RUN_KEYS - set(run)
                need(not missing, f"{where}/{policy}: missing {missing}")
                for k in ("ttft_chunks", "itl_chunks",
                          "queue_wait_chunks"):
                    pct = run.get(k)
                    need(isinstance(pct, dict) and
                         _PCTL_KEYS <= set(pct),
                         f"{where}/{policy}: {k} lacks p50/p99")
                slo = run.get("slo") or {}
                need("ttft_attainment" in slo and "attainment" in slo,
                     f"{where}/{policy}: slo block incomplete")
                need(run.get("finished") == run.get("sessions"),
                     f"{where}/{policy}: not every session finished")
    der = payload.get("derived") or {}
    need("any_oversubscribed_win" in der,
         "derived lacks any_oversubscribed_win")
    return errs


def run(emit) -> None:
    """benchmarks.run entry point: full bench, committed artifact."""
    payload = _bench(smoke=False, emit=emit)
    with open(OUT_JSON, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    emit("bench_serving_json", 0.0, f"written to {OUT_JSON}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small scale (CI): tconst arch only, "
                         "12 sessions per run")
    ap.add_argument("--out", default=OUT_JSON,
                    help=f"output path (default {OUT_JSON})")
    ap.add_argument("--check", metavar="JSON",
                    help="validate an existing payload and exit")
    args = ap.parse_args(argv)
    if args.check:
        with open(args.check) as f:
            errs = validate_payload(json.load(f))
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        print(f"{args.check}: " + ("INVALID" if errs else "ok"))
        return 1 if errs else 0

    def emit(name, value, derived=""):
        print(f"{name},{value},{derived}", flush=True)

    payload = _bench(smoke=args.smoke, emit=emit)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    errs = validate_payload(payload)
    if errs:
        for e in errs:
            print(f"schema: {e}", file=sys.stderr)
        return 1
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Session/scheduler serving API: resync-boundary correctness of the
fused (on-device, compacted row-wise) synchronisation, continuous
batching with staggered admission, pluggable cache layouts
(dense / paged / int8), EOS early termination, and the zero-host-sync
decode chunk."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.core import tconst as TC
from repro.models import layouts as LT
from repro.models.api import build_decode, build_model, decode_chunk
from repro.serving.engine import Engine
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session


@pytest.fixture(scope="module", params=["tconst", "tlin"])
def setup(request):
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode=request.param)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _solo(api, params, prompt, n, max_len=128):
    eng = Engine(api, params, max_len=max_len)
    return eng.generate({"tokens": jnp.asarray(prompt)[None]}, n)[0].tolist()


# ---------------------------------------------------------------------------
# Resync-boundary correctness
# ---------------------------------------------------------------------------


def test_chunk_across_boundary_matches_stepwise_reference(setup):
    """A chunked (single lax.scan, on-device compacted resync) generation
    crossing several W_og boundaries must equal the step-at-a-time
    reference path where the resync decision is made on host."""
    cfg, api, params = setup
    p = {"tokens": jnp.ones((2, 12), jnp.int32)}   # phase 12 % 8 = 4
    fast = Engine(api, params, max_len=128).generate(p, 30)
    ref_eng = Engine(api, params, max_len=128)
    ref = ref_eng.generate(p, 30, record_stats=True)
    np.testing.assert_array_equal(fast, ref)
    if cfg.attention_mode == "tconst":
        assert [s.kind for s in ref_eng.stats].count("miss") >= 3


def test_fused_step_resyncs_on_device(setup):
    """At gen_len == W_og the fused step folds the window into history
    inside the jitted step (no host decision) and matches
    sync_rows + raw_step."""
    cfg, api, params = setup
    dec = api.decode
    w_og = cfg.tconst.w_og
    _, state = dec.prefill(params, {"tokens": jnp.ones((1, w_og),
                                                       jnp.int32)}, 64)
    assert bool(dec.sync_mask(state).all())        # window exactly full
    tok = jnp.array([3], jnp.int32)
    lg_fused, st_fused = jax.jit(dec.step)(params, state, tok)
    synced = dec.sync_rows(params, state, dec.sync_mask(state))
    lg_ref, st_ref = dec.raw_step(params, synced, tok)
    np.testing.assert_allclose(np.asarray(lg_fused), np.asarray(lg_ref),
                               atol=1e-5)
    assert int(st_fused.bookkeeping["gen_len"][0]) == 1
    assert int(st_fused.bookkeeping["hist_len"][0]) == w_og


def test_row_selective_resync_leaves_other_rows_untouched(setup):
    """Only rows at the W_og boundary are resynced: a mid-phase row must
    come through resync_rows bit-identical."""
    cfg, api, params = setup
    dec = api.decode
    _, state = dec.prefill(params, {"tokens": jnp.ones((2, 12),
                                                       jnp.int32)}, 64)
    cache = state.merged()
    rows = jnp.array([True, False])
    out = TC.resync_rows(params, cache, cfg, rows, cfg.attention_mode)
    assert int(out["gen_len"][0]) == 0             # row 0 folded
    assert int(out["gen_len"][1]) == int(cache["gen_len"][1])
    for k in cache:
        ax = TC.CACHE_BATCH_AXES[k]
        old_row1 = np.take(np.asarray(cache[k]), 1, axis=ax)
        new_row1 = np.take(np.asarray(out[k]), 1, axis=ax)
        np.testing.assert_array_equal(old_row1, new_row1)


def test_compacted_sync_rows_matches_pr1_full_batch_resync(setup):
    """The compacted while-loop resync (gather masked rows, sync at batch
    size 1, scatter back — non-masked rows never computed) must produce
    the cache of the PR-1 compute-all-then-select path for any row mask:
    bit-identical bookkeeping and unmasked rows, float KV within fusion
    noise (the while-loop body fuses differently than the unrolled
    batch pass)."""
    cfg, api, params = setup
    dec = api.decode
    _, state = dec.prefill(params, {"tokens": jnp.ones((3, 12),
                                                       jnp.int32)}, 64)
    cache = state.merged()
    for rows in ([True, False, True], [False, False, False],
                 [True, True, True]):
        mask = jnp.array(rows)
        ref = TC.resync_rows(params, cache, cfg, mask, cfg.attention_mode)
        got = jax.jit(lambda c, m: TC.resync_rows_compacted(
            params, c, cfg, m, cfg.attention_mode))(cache, mask)
        for k in cache:
            a, b = np.asarray(got[k]), np.asarray(ref[k])
            if np.issubdtype(a.dtype, np.floating):
                np.testing.assert_allclose(a, b, atol=1e-5,
                                           err_msg=str((rows, k)))
            else:
                np.testing.assert_array_equal(a, b, err_msg=str((rows, k)))
        # unmasked rows: bit-identical (never touched by the loop)
        for i, r in enumerate(rows):
            if r:
                continue
            for k in cache:
                ax = TC.CACHE_BATCH_AXES[k]
                np.testing.assert_array_equal(
                    np.take(np.asarray(got[k]), i, axis=ax),
                    np.take(np.asarray(cache[k]), i, axis=ax))


def test_compacted_step_tokens_match_pr1_maybe_resync(setup):
    """Token-level PR-1 equivalence: greedy decode of a mixed-phase batch
    through the v2 fused step (compacted sync_rows) must emit exactly
    the tokens of the PR-1 path (monolithic maybe_resync: full-batch
    compute + row select) across several W_og boundaries."""
    cfg, api, params = setup
    dec = api.decode

    def pr1_step(p, st, tok):
        cache = TC.maybe_resync(p, st.merged(), cfg, cfg.attention_mode)
        lg, cache = TC.decode_step(p, cache, tok, cfg,
                                   mode=cfg.attention_mode)
        return lg, dec._rewrap(st, cache)

    _, state = dec.prefill(params, {"tokens": jnp.ones((2, 12),
                                                       jnp.int32)}, 96)
    s_new = s_old = state
    tok_new = tok_old = jnp.array([5, 9], jnp.int32)
    new_step = jax.jit(dec.step)
    old_step = jax.jit(pr1_step)
    for _ in range(20):
        lg_new, s_new = new_step(params, s_new, tok_new)
        lg_old, s_old = old_step(params, s_old, tok_old)
        tok_new = jnp.argmax(lg_new, -1).astype(jnp.int32)
        tok_old = jnp.argmax(lg_old, -1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(tok_new),
                                      np.asarray(tok_old))


# ---------------------------------------------------------------------------
# Continuous batching: staggered admission, variable prompt lengths
# ---------------------------------------------------------------------------


def test_staggered_sessions_match_solo_generation(setup):
    """Two sessions with different prompt lengths, admitted at different
    times (different W_og phases inside one batch), must each produce
    exactly the tokens of their single-session generation."""
    cfg, api, params = setup
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)     # len 9
    pb = ((np.arange(1, 14) * 7) % cfg.vocab_size).astype(np.int32)

    sched = SlotScheduler(api.decode, params, slots=2, max_len=128,
                          chunk_size=4)
    sa = sched.submit(Session(pa, max_new_tokens=25))
    sched.step()       # A runs a chunk alone -> staggered resync phases
    sb = sched.submit(Session(pb, max_new_tokens=21))
    sched.run()
    assert sa.done and sb.done
    assert sa.tokens == _solo(api, params, pa, 25)
    assert sb.tokens == _solo(api, params, pb, 21)


def test_sessions_stream_through_callback_and_reuse_slots(setup):
    cfg, api, params = setup
    streamed = []
    sched = SlotScheduler(api.decode, params, slots=1, max_len=128,
                          chunk_size=4)
    for i in range(3):                       # 3 sessions through 1 slot
        sched.submit(Session(np.full(5 + i, 2, np.int32),
                             max_new_tokens=6,
                             on_token=lambda s, t: streamed.append(
                                 (s.sid, t))))
    sched.run()
    assert len(streamed) == 18
    assert len({sid for sid, _ in streamed}) == 3


def test_eos_early_termination_frees_slot(setup):
    """A session whose EOS id is sampled mid-stream stops at the EOS
    (inclusive), its on-device done flag freezes the row inside the
    chunk, and the scheduler evicts it at the chunk boundary."""
    cfg, api, params = setup
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    ref = _solo(api, params, pa, 25)
    # an eos whose FIRST occurrence is mid-stream; degenerate all-same
    # streams (possible for other seeds/configs) can't test truncation
    eos = next((t for t in ref if ref.index(t) >= 2), None)
    if eos is None:
        pytest.skip("greedy reference stream has no mid-stream-first token")
    cut = ref.index(eos) + 1
    sched = SlotScheduler(api.decode, params, slots=2, max_len=128,
                          chunk_size=4)
    se = sched.submit(Session(pa, max_new_tokens=25, eos_id=eos))
    sched.run()
    assert se.done
    assert se.tokens == ref[:cut]
    assert sched.n_active == 0
    # the freed slot's state is cleared: no stale done/phase flags
    assert not bool(np.asarray(
        sched.state.bookkeeping["done"]).any())


# ---------------------------------------------------------------------------
# Cache layouts: paged / int8 parity and accounting
# ---------------------------------------------------------------------------


def test_paged_layout_staggered_sessions_token_identical(setup):
    """Paged layout with an UNDER-SIZED pool (the scheduler allocates and
    recycles pages at admission/eviction) must be token-identical to the
    dense path under staggered multi-slot admission."""
    cfg, api, params = setup
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    pb = ((np.arange(1, 14) * 7) % cfg.vocab_size).astype(np.int32)
    spec = LT.LayoutSpec(kind="paged", page_size=16, pool_pages=10)
    dec = build_decode(cfg, spec)
    sched = SlotScheduler(dec, params, slots=2, max_len=128, chunk_size=4)
    sa = sched.submit(Session(pa, max_new_tokens=25))
    sched.step()
    sb = sched.submit(Session(pb, max_new_tokens=21))
    sched.run()
    assert sa.tokens == _solo(api, params, pa, 25)
    assert sb.tokens == _solo(api, params, pb, 21)

    dense_bytes = SlotScheduler(api.decode, params, slots=2,
                                max_len=128).kv_bytes()
    if cfg.attention_mode == "tlin":
        # the O(N) history KV is paged: a 10/16 pool beats dense, and
        # pages were recycled back to the pool after eviction
        assert sched.kv_bytes() < dense_bytes
        assert len(sched.free_pages) == 10
    else:
        # pure tconst KV is already O(1): paged degenerates to dense and
        # the scheduler must not gate admission on the (unused) pool —
        # a session "needing" more pages than a tiny pool holds still
        # runs, because nothing is actually stored in pages
        assert sched.kv_bytes() == dense_bytes
        assert not sched._paged
        tiny_dec = build_decode(cfg, LT.LayoutSpec(
            kind="paged", page_size=16, pool_pages=2))
        tiny = SlotScheduler(tiny_dec, params, slots=1, max_len=128,
                             chunk_size=4)
        s = tiny.submit(Session(pa, max_new_tokens=25))   # needs 3 "pages"
        tiny.run()
        assert s.done and s.tokens == _solo(api, params, pa, 25)


def test_int8_layout_tolerance_and_bytes(setup):
    """int8 KV must (a) reproduce the dense KV within the symmetric-int8
    rounding bound (scale = vecmax/127 => error <= scale/2 per element),
    (b) shrink kv_bytes ~4x vs float32, (c) decode end-to-end."""
    cfg, api, params = setup
    dec8 = build_decode(cfg, "int8")
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    _, dense_state = api.decode.prefill(params, batch, 64)
    _, q_state = dec8.prefill(params, batch, 64)
    dense_kv = dense_state.merged()
    deq_kv = q_state.merged()
    for k in TC.QUANT_FIELDS:
        if k not in dense_kv:
            continue
        x = np.asarray(dense_kv[k], np.float32)
        y = np.asarray(deq_kv[k], np.float32)
        bound = np.max(np.abs(x), axis=-1, keepdims=True) / 127.0 * 0.5 \
            + 1e-7
        assert (np.abs(x - y) <= bound + 1e-6).all(), k

    ratio = dense_state.kv_bytes() / q_state.kv_bytes()
    hd = cfg.resolved_head_dim            # f32: 4 / (1 + 4/head_dim)
    assert abs(ratio - 4.0 / (1.0 + 4.0 / hd)) < 0.05

    out = Engine(api, params, max_len=128, layout="int8").generate(
        {"tokens": jnp.ones((1, 9), jnp.int32)}, 16)
    assert out.shape == (1, 16) and (out >= 0).all()


def test_engine_layouts_greedy_parity(setup):
    """Uniform-batch Engine: paged (full pool — no allocator needed) is
    token-identical to dense."""
    cfg, api, params = setup
    p = {"tokens": jnp.ones((2, 12), jnp.int32)}
    ref = Engine(api, params, max_len=128).generate(p, 24)
    got = Engine(api, params, max_len=128, layout="paged").generate(p, 24)
    np.testing.assert_array_equal(got, ref)


def test_undersized_pool_rejects_full_batch_prefill_iff_paged_fields(setup):
    """An under-sized pool has no allocator on the full-batch prefill
    path, so prefill must refuse it — but ONLY when the cache actually
    pages something (tlin's history KV); pure-tconst caches store
    nothing in pages and must prefill fine."""
    cfg, api, params = setup
    spec = LT.LayoutSpec(kind="paged", page_size=16, pool_pages=2)
    dec = build_decode(cfg, spec)
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    if cfg.attention_mode == "tlin":
        with pytest.raises(ValueError, match="under-sized paged pool"):
            dec.prefill(params, batch, 128)
    else:
        _, state = dec.prefill(params, batch, 128)
        assert state.slots == 2


# ---------------------------------------------------------------------------
# Zero per-token host syncs
# ---------------------------------------------------------------------------


def _subjaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def _jaxpr_has_host_comms(jaxpr) -> bool:
    bad = ("callback", "infeed", "outfeed", "host")
    for eqn in jaxpr.eqns:
        if any(b in eqn.primitive.name for b in bad):
            return True
        for v in eqn.params.values():
            for inner in _subjaxprs(v):
                if _jaxpr_has_host_comms(inner):
                    return True
    return False


def test_decode_chunk_is_single_dispatch_without_host_comms(setup):
    """A k-token decode chunk is one traced computation: its jaxpr holds
    no callback/transfer primitives, and a scheduler run records only
    'chunk' StepStats — never per-token 'hit'/'miss' entries."""
    cfg, api, params = setup
    dec = api.decode
    state = jax.eval_shape(lambda: dec.init_state(2, 64))
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    temps = jax.ShapeDtypeStruct((2,), jnp.float32)
    act = jax.ShapeDtypeStruct((2,), jnp.bool_)
    eos = jax.ShapeDtypeStruct((2,), jnp.int32)
    closed = jax.make_jaxpr(
        lambda p, s, t, k, tp, a, e: decode_chunk(dec, p, s, t, k, tp, a,
                                                  n_steps=12, eos=e))(
        jax.eval_shape(api.init, jax.random.PRNGKey(0)),
        state, tok, key, temps, act, eos)
    assert not _jaxpr_has_host_comms(closed.jaxpr)

    sched = SlotScheduler(dec, params, slots=2, max_len=128, chunk_size=6)
    sched.submit(Session(np.full(12, 1, np.int32), max_new_tokens=13))
    sched.run()
    kinds = {s.kind for s in sched.stats}
    assert kinds == {"chunk"}
    # 1 prefill token + 12 chunked tokens in exactly 2 dispatches
    assert len(sched.stats) == 2
    assert all(s.tokens == 6 for s in sched.stats)


def test_stepstats_compiled_tagging(setup):
    """Entries whose wall-clock includes the one-time jit compile carry
    compiled=True (exactly the first dispatch of each kind/signature),
    so throughput aggregation can exclude them — a cold first chunk
    must never skew BENCH_inference tok/s again."""
    cfg, api, params = setup
    eng = Engine(api, params, max_len=64)
    p = {"tokens": jnp.ones((1, 8), jnp.int32)}
    eng.generate(p, 6, record_stats=True)
    by_kind = {}
    for s in eng.stats:
        by_kind.setdefault(s.kind, []).append(s.compiled)
    for kind, flags in by_kind.items():
        assert flags[0] and not any(flags[1:]), (kind, flags)
    eng.stats.clear()
    eng.generate(p, 6, record_stats=True)      # warm: nothing compiles
    assert not any(s.compiled for s in eng.stats)


# ---------------------------------------------------------------------------
# DecodeState partition (cache accounting)
# ---------------------------------------------------------------------------


def test_decode_state_partition_and_bytes(setup):
    cfg, api, params = setup
    state = api.init_cache(2, 256)
    assert set(state.bookkeeping) == {"tokens", "hist_len", "gen_len",
                                      "done", "ctx_valid"}
    assert all(k.endswith("_k") or k.endswith("_v") for k in state.kv)
    # partition-based accounting agrees with the core's name-based one
    assert state.kv_bytes() == TC.kv_cache_bytes(state.merged())
    if cfg.attention_mode == "tconst":
        # O(1): kv bytes independent of max_len; bookkeeping is the only
        # O(N) residue (int32 id buffer)
        big = api.init_cache(2, 1 << 14)
        assert big.kv_bytes() == state.kv_bytes()

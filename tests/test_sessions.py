"""Session/scheduler serving API: resync-boundary correctness of the
fused (on-device, lax.cond) synchronisation, continuous batching with
staggered admission, and the zero-host-sync decode chunk."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.core import tconst as TC
from repro.models.api import build_model, decode_chunk
from repro.serving.engine import Engine
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session


@pytest.fixture(scope="module", params=["tconst", "tlin"])
def setup(request):
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode=request.param)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _solo(api, params, prompt, n, max_len=128):
    eng = Engine(api, params, max_len=max_len)
    return eng.generate({"tokens": jnp.asarray(prompt)[None]}, n)[0].tolist()


# ---------------------------------------------------------------------------
# Resync-boundary correctness
# ---------------------------------------------------------------------------


def test_chunk_across_boundary_matches_stepwise_reference(setup):
    """A chunked (single lax.scan, on-device lax.cond resync) generation
    crossing several W_og boundaries must equal the step-at-a-time
    reference path where the resync decision is made on host."""
    cfg, api, params = setup
    p = {"tokens": jnp.ones((2, 12), jnp.int32)}   # phase 12 % 8 = 4
    fast = Engine(api, params, max_len=128).generate(p, 30)
    ref_eng = Engine(api, params, max_len=128)
    ref = ref_eng.generate(p, 30, record_stats=True)
    np.testing.assert_array_equal(fast, ref)
    if cfg.attention_mode == "tconst":
        assert [s.kind for s in ref_eng.stats].count("miss") >= 3


def test_fused_step_resyncs_on_device(setup):
    """At gen_len == W_og the fused step folds the window into history
    inside the jitted step (no host decision) and matches sync+step."""
    cfg, api, params = setup
    dec = api.decode
    w_og = cfg.tconst.w_og
    _, state = dec.prefill(params, {"tokens": jnp.ones((1, w_og),
                                                       jnp.int32)}, 64)
    assert bool(dec.needs_sync(state).all())       # window exactly full
    tok = jnp.array([3], jnp.int32)
    lg_fused, st_fused = jax.jit(dec.step)(params, state, tok)
    lg_ref, st_ref = dec.raw_step(params, dec.sync(params, state), tok)
    np.testing.assert_allclose(np.asarray(lg_fused), np.asarray(lg_ref),
                               atol=1e-5)
    assert int(st_fused.bookkeeping["gen_len"][0]) == 1
    assert int(st_fused.bookkeeping["hist_len"][0]) == w_og


def test_row_selective_resync_leaves_other_rows_untouched(setup):
    """Only rows at the W_og boundary are resynced: a mid-phase row must
    come through resync_rows bit-identical."""
    cfg, api, params = setup
    dec = api.decode
    _, state = dec.prefill(params, {"tokens": jnp.ones((2, 12),
                                                       jnp.int32)}, 64)
    cache = state.merged()
    rows = jnp.array([True, False])
    out = TC.resync_rows(params, cache, cfg, rows, cfg.attention_mode)
    assert int(out["gen_len"][0]) == 0             # row 0 folded
    assert int(out["gen_len"][1]) == int(cache["gen_len"][1])
    for k in cache:
        ax = TC.CACHE_BATCH_AXES[k]
        old_row1 = np.take(np.asarray(cache[k]), 1, axis=ax)
        new_row1 = np.take(np.asarray(out[k]), 1, axis=ax)
        np.testing.assert_array_equal(old_row1, new_row1)


# ---------------------------------------------------------------------------
# Continuous batching: staggered admission, variable prompt lengths
# ---------------------------------------------------------------------------


def test_staggered_sessions_match_solo_generation(setup):
    """Two sessions with different prompt lengths, admitted at different
    times (different W_og phases inside one batch), must each produce
    exactly the tokens of their single-session generation."""
    cfg, api, params = setup
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)     # len 9
    pb = ((np.arange(1, 14) * 7) % cfg.vocab_size).astype(np.int32)

    sched = SlotScheduler(api.decode, params, slots=2, max_len=128,
                          chunk_size=4)
    sa = sched.submit(Session(pa, max_new_tokens=25))
    sched.step()       # A runs a chunk alone -> staggered resync phases
    sb = sched.submit(Session(pb, max_new_tokens=21))
    sched.run()
    assert sa.done and sb.done
    assert sa.tokens == _solo(api, params, pa, 25)
    assert sb.tokens == _solo(api, params, pb, 21)


def test_sessions_stream_through_callback_and_reuse_slots(setup):
    cfg, api, params = setup
    streamed = []
    sched = SlotScheduler(api.decode, params, slots=1, max_len=128,
                          chunk_size=4)
    for i in range(3):                       # 3 sessions through 1 slot
        sched.submit(Session(np.full(5 + i, 2, np.int32),
                             max_new_tokens=6,
                             on_token=lambda s, t: streamed.append(
                                 (s.sid, t))))
    sched.run()
    assert len(streamed) == 18
    assert len({sid for sid, _ in streamed}) == 3


# ---------------------------------------------------------------------------
# Zero per-token host syncs
# ---------------------------------------------------------------------------


def _subjaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def _jaxpr_has_host_comms(jaxpr) -> bool:
    bad = ("callback", "infeed", "outfeed", "host")
    for eqn in jaxpr.eqns:
        if any(b in eqn.primitive.name for b in bad):
            return True
        for v in eqn.params.values():
            for inner in _subjaxprs(v):
                if _jaxpr_has_host_comms(inner):
                    return True
    return False


def test_decode_chunk_is_single_dispatch_without_host_comms(setup):
    """A k-token decode chunk is one traced computation: its jaxpr holds
    no callback/transfer primitives, and a scheduler run records only
    'chunk' StepStats — never per-token 'hit'/'miss' entries."""
    cfg, api, params = setup
    dec = api.decode
    state = jax.eval_shape(lambda: dec.init_state(2, 64))
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    temps = jax.ShapeDtypeStruct((2,), jnp.float32)
    act = jax.ShapeDtypeStruct((2,), jnp.bool_)
    closed = jax.make_jaxpr(
        lambda p, s, t, k, tp, a: decode_chunk(dec, p, s, t, k, tp, a,
                                               n_steps=12))(
        jax.eval_shape(api.init, jax.random.PRNGKey(0)),
        state, tok, key, temps, act)
    assert not _jaxpr_has_host_comms(closed.jaxpr)

    sched = SlotScheduler(dec, params, slots=2, max_len=128, chunk_size=6)
    sched.submit(Session(np.full(12, 1, np.int32), max_new_tokens=13))
    sched.run()
    kinds = {s.kind for s in sched.stats}
    assert kinds == {"chunk"}
    # 1 prefill token + 12 chunked tokens in exactly 2 dispatches
    assert len(sched.stats) == 2
    assert all(s.tokens == 6 for s in sched.stats)


# ---------------------------------------------------------------------------
# DecodeState partition (cache accounting)
# ---------------------------------------------------------------------------


def test_decode_state_partition_and_bytes(setup):
    cfg, api, params = setup
    state = api.init_cache(2, 256)
    assert set(state.bookkeeping) == {"tokens", "hist_len", "gen_len",
                                      "ctx_valid"}
    assert all(k.endswith("_k") or k.endswith("_v") for k in state.kv)
    # partition-based accounting agrees with the core's name-based one
    assert state.kv_bytes() == TC.kv_cache_bytes(state.merged())
    if cfg.attention_mode == "tconst":
        # O(1): kv bytes independent of max_len; bookkeeping is the only
        # O(N) residue (int32 id buffer)
        big = api.init_cache(2, 1 << 14)
        assert big.kv_bytes() == state.kv_bytes()

"""Workload generator + serving-telemetry units (no model, no device).

The serving bench's comparisons are only meaningful if (1) the traffic
trace is a pure function of ``(spec, seed)`` — both policies must replay
the SAME sessions — and (2) the telemetry aggregation is exact on known
inputs.  Everything here is host-side and fast; the scheduler-integrated
end is covered in ``test_serving_policy.py``.
"""
import numpy as np
import pytest

from repro.serving.metrics import (SessionRecord, ServingTelemetry,
                                   percentile)
from repro.serving.session import Session
from repro.serving.workload import (Arrival, WorkloadSpec,
                                    generate_workload)

VOCAB = 512


def _spec(**kw):
    base = dict(n_sessions=40, vocab=VOCAB)
    base.update(kw)
    return WorkloadSpec(**base)


# ---------------------------------------------------------------------------
# workload generation
# ---------------------------------------------------------------------------


def test_workload_deterministic_in_spec_and_seed():
    a = generate_workload(_spec(), seed=7)
    b = generate_workload(_spec(), seed=7)
    assert len(a) == len(b) == 40
    for x, y in zip(a, b):
        assert x.at_chunk == y.at_chunk
        np.testing.assert_array_equal(x.session.prompt, y.session.prompt)
        assert x.session.max_new_tokens == y.session.max_new_tokens
        assert x.session.seed == y.session.seed
        assert x.session.priority == y.session.priority
        assert x.session.slo_ttft_chunks == y.session.slo_ttft_chunks
    c = generate_workload(_spec(), seed=8)
    assert any(x.at_chunk != z.at_chunk or
               not np.array_equal(x.session.prompt, z.session.prompt)
               for x, z in zip(a, c))


def test_workload_sorted_and_shaped_by_mixes():
    arrivals = generate_workload(_spec(
        prompt_mix=((1.0, 5, 9),), output_mix=((1.0, 3, 4),)), seed=0)
    chunks = [a.at_chunk for a in arrivals]
    assert chunks == sorted(chunks) and chunks[0] >= 0
    for a in arrivals:
        assert 5 <= len(a.session.prompt) <= 9
        assert 3 <= a.session.max_new_tokens <= 4
        assert a.session.prompt.dtype == np.int32
        assert int(a.session.prompt.max()) < VOCAB


def test_bursty_arrivals_pile_up_on_shared_chunks():
    arrivals = generate_workload(_spec(
        arrival="bursty", burst_size=8, burst_every=50.0), seed=1)
    chunks = [a.at_chunk for a in arrivals]
    # bursts drop many sessions on one chunk: far fewer distinct chunks
    # than sessions (a poisson trace at matched load has no such pileup)
    assert len(set(chunks)) < len(chunks) // 2


def test_shared_prefix_population_reuses_the_common_heads():
    spec = _spec(shared_frac=1.0, n_prefixes=2, prefix_len=8,
                 prompt_mix=((1.0, 4, 6),))
    arrivals = generate_workload(spec, seed=2)
    heads = {a.session.prompt[:8].tobytes() for a in arrivals}
    assert len(heads) <= 2                     # every prompt uses one of 2
    assert all(len(a.session.prompt) > 8 for a in arrivals)


def test_repeat_population_reissues_verbatim_prompts():
    arrivals = generate_workload(_spec(repeat_frac=0.9), seed=3)
    seen = set()
    repeats = 0
    for a in arrivals:
        key = a.session.prompt.tobytes()
        repeats += key in seen
        seen.add(key)
    assert repeats >= len(arrivals) // 2


def test_slo_slice_carries_targets_and_priority():
    every = generate_workload(_spec(slo_frac=1.0, slo_ttft_chunks=5,
                                    slo_itl_chunks=2, slo_priority=3),
                              seed=4)
    for a in every:
        assert a.session.slo_ttft_chunks == 5
        assert a.session.slo_itl_chunks == 2
        assert a.session.priority == 3
    none = generate_workload(_spec(slo_frac=0.0), seed=4)
    assert all(a.session.slo_ttft_chunks is None for a in none)
    assert all(a.session.priority == 0 for a in none)


def test_max_prompt_len_clips():
    arrivals = generate_workload(_spec(prompt_mix=((1.0, 30, 60),)),
                                 seed=5, max_prompt_len=12)
    assert max(len(a.session.prompt) for a in arrivals) <= 12


@pytest.mark.parametrize("bad", [
    dict(n_sessions=0), dict(arrival="uniform"), dict(rate=0.0),
    dict(arrival="bursty", burst_size=0), dict(slo_frac=1.5),
    dict(prompt_mix=()), dict(prompt_mix=((1.0, 9, 4),)),
    dict(output_mix=((0.0, 1, 2),)),
])
def test_workload_spec_validation(bad):
    with pytest.raises(ValueError):
        _spec(**bad)


# ---------------------------------------------------------------------------
# telemetry aggregation
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 99) == 5.0           # a value a session saw
    assert percentile(xs, 0) == 1.0
    assert percentile([], 50) is None


def _session(**kw):
    base = dict(prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    base.update(kw)
    return Session(**base)


def test_telemetry_ttft_itl_and_slo_accounting():
    tel = ServingTelemetry()
    s = _session(slo_ttft_chunks=3, slo_itl_chunks=2)
    tel.on_submit(s, clock=2)
    tel.on_admit(s, clock=4, source="cold")
    tel.on_tokens(s, 1, clock=4, compiled=True)    # first token, compiling
    tel.on_tokens(s, 2, clock=6, compiled=False)   # gap 2, then same-tick 0
    tel.on_tokens(s, 1, clock=9, compiled=False)   # gap 3: ITL SLO miss
    tel.on_retire(s, clock=9)
    rec = tel.records[s.sid]
    assert rec.queue_wait_chunks == 2
    assert rec.ttft_chunks == 2 and rec.ttft_ok is True
    assert rec.ttft_compiled and rec.ttft_seconds is None   # excluded
    assert rec.itl_gaps_chunks == [2, 0, 3]
    assert rec.itl_ok is False and rec.slo_ok is False
    assert rec.tokens_out == 4 and rec.done


def test_telemetry_starved_slo_session_counts_as_miss():
    tel = ServingTelemetry()
    s = _session(slo_ttft_chunks=4)
    tel.on_submit(s, clock=0)
    assert tel.records[s.sid].ttft_ok is False       # no token ever
    t = _session()                                   # no SLO at all
    tel.on_submit(t, clock=0)
    assert tel.records[t.sid].slo_ok is None
    summary = tel.summary()
    assert summary["sessions"] == 2
    assert summary["slo"]["sessions_with_slo"] == 1
    assert summary["slo"]["attainment"] == 0.0


def test_telemetry_summary_shapes():
    tel = ServingTelemetry()
    for clock, s in enumerate([_session(), _session(slo_ttft_chunks=9)]):
        tel.on_submit(s, clock=clock)
        tel.on_admit(s, clock=clock + 1, source="cold")
        tel.on_tokens(s, 1, clock=clock + 1, compiled=False)
        tel.on_tokens(s, 1, clock=clock + 2, compiled=False)
        tel.on_retire(s, clock=clock + 2)
    tel.on_tick(1, n_active=2, n_pending=0, free_pages=4, total_pages=8)
    s = tel.summary()
    assert s["finished"] == 2 and s["tokens_out"] == 4
    assert s["ttft_chunks"]["p50"] == 1.0
    assert s["itl_chunks"]["p99"] == 1.0
    assert s["queue_wait_chunks"]["p50"] == 1.0
    assert s["ttft_seconds_warm"]["n"] == 2
    assert s["slo"]["ttft_attainment"] == 1.0
    assert s["pool_occupancy_mean"] == 0.5


def test_session_record_single_token_stream_meets_itl():
    rec = SessionRecord(sid=0, slo_itl_chunks=1)
    rec.tokens_out = 1
    assert rec.itl_ok is True                   # no gaps to violate

"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an OPTIONAL dev dependency (see pyproject.toml): when
it is not installed this module skips instead of breaking collection of
the whole suite.  CI sets ``REPRO_REQUIRE_HYPOTHESIS=1`` so a broken
install FAILS collection loudly there — before the guard, a CI image
that silently lost the dependency reported this whole file as "passed"
while running zero examples.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
    import hypothesis  # noqa: F401  (ImportError = loud CI failure)
else:
    pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import ModelConfig, TConstConfig
from repro.core import tconst as T
from repro.kernels.xla_flash import flash_attention
from repro.layers import attention as A
from repro.layers import moe as M
from repro.data import tokenizer

SET = dict(max_examples=12, deadline=None)


@settings(**SET)
@given(lq=st.integers(1, 24), lk=st.integers(1, 24),
       qb=st.sampled_from([4, 8, 16]), kb=st.sampled_from([4, 8, 16]),
       causal=st.booleans(), window_raw=st.sampled_from([0, 3, 8]))
def test_flash_equals_naive_for_any_blocking(lq, lk, qb, kb, causal,
                                             window_raw):
    """Block sizes are an implementation detail: any (qb, kb) must give the
    same output as the naive reference.  (window implies causal in this
    framework, so the non-causal draws drop the window.)"""
    window = window_raw if causal else 0
    key = jax.random.PRNGKey(lq * 31 + lk)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, lq, 2, 8))
    k = jax.random.normal(ks[1], (1, lk, 2, 8))
    v = jax.random.normal(ks[2], (1, lk, 2, 8))
    qp = jnp.arange(lk - lq, lk, dtype=jnp.int32)    # queries at the end
    kp = jnp.arange(lk, dtype=jnp.int32)
    o = flash_attention(q, k, v, qp, kp, window, causal, 0.0, qb, kb)
    mode = "sliding" if window else ("causal" if causal else "full")
    mask = A.make_mask(qp, kp, mode, window)
    o_ref = A.sdpa(q, k, v, mask)
    valid = np.asarray(jnp.isfinite(o_ref)).all()
    assert valid
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


@settings(**SET)
@given(st.integers(0, 2**31 - 1))
def test_attention_rows_are_convex_combinations(seed):
    """Softmax attention output lies in the convex hull of V rows: its
    per-dim values are bounded by V's min/max."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 6, 2, 8)) * 3
    k = jax.random.normal(ks[1], (1, 9, 2, 8))
    v = jax.random.normal(ks[2], (1, 9, 2, 8))
    o = A.sdpa(q, k, v, None)
    vmin = jnp.min(v, axis=1, keepdims=True)
    vmax = jnp.max(v, axis=1, keepdims=True)
    # GQA grouping: compare per kv-head group
    og = o.reshape(1, 6, 2, 1, 8)
    assert bool(jnp.all(og <= vmax[:, :, :, None] + 1e-5))
    assert bool(jnp.all(og >= vmin[:, :, :, None] - 1e-5))


@settings(**SET)
@given(w_oh=st.sampled_from([4, 8]), w_og=st.sampled_from([4, 8]),
       h=st.integers(0, 2), nchunks=st.integers(1, 3))
def test_tconst_cache_constant_for_any_window_config(w_oh, w_og, h,
                                                     nchunks):
    cfg = ModelConfig(d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=61, n_layers=(h + 2), dtype="float32",
                      attention_mode="tconst",
                      tconst=TConstConfig(w_oh=w_oh, w_og=w_og, h=h))
    c_small = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 64))
    c_large = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 8192))
    assert c_small == c_large


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), top_k=st.integers(1, 3))
def test_moe_combine_weights_sum_to_at_most_one(seed, top_k):
    """Per token, the (renormalised, possibly capacity-dropped) combine
    weights sum to <= 1, and == 1 when nothing is dropped."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (32, 4))
    dispatch, combine, _ = M.route_topk(logits, top_k, capacity=32)
    sums = np.asarray(jnp.sum(combine, axis=(1, 2)))
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)   # no drops: exact
    _, combine2, _ = M.route_topk(logits, top_k, capacity=4)
    sums2 = np.asarray(jnp.sum(combine2, axis=(1, 2)))
    assert (sums2 <= 1.0 + 1e-5).all()


@settings(**SET)
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(s):
    ids = tokenizer.encode(s)
    assert tokenizer.decode(ids) == s


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_decode_attend_is_permutation_invariant_in_dead_slots(seed):
    """Values in cache slots beyond valid_len must not affect output."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    from repro.kernels.ref import decode_reference
    q = jax.random.normal(ks[0], (2, 4, 16))
    k = jax.random.normal(ks[1], (2, 12, 2, 16))
    v = jax.random.normal(ks[2], (2, 12, 2, 16))
    vl = jnp.array([5, 9])
    o1 = decode_reference(q, k, v, vl)
    noise = jax.random.normal(ks[3], (2, 12, 2, 16)) * 100
    slot = jnp.arange(12)[None, :, None, None]
    k2 = jnp.where(slot >= vl[:, None, None, None], k + noise, k)
    v2 = jnp.where(slot >= vl[:, None, None, None], v + noise, v)
    o2 = decode_reference(q, k2, v2, vl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


# ---------------------------------------------------------------------------
# Speculative acceptance (PR 10): the pure accept/rollback state machine
# ---------------------------------------------------------------------------


def _acceptance_reference(feed, samples, budget, live, eos):
    """Pure-Python oracle for ``models.api.speculative_acceptance``."""
    B, C = feed.shape
    ms, hits = [], []
    for b in range(B):
        a = 0
        while a < C - 1 and feed[b, a + 1] == samples[b, a]:
            a += 1
        m = min(a + 1, max(int(budget[b]), 1))
        has, first = False, 0
        if eos is not None and eos[b] >= 0:
            occ = [c for c in range(C) if samples[b, c] == eos[b]]
            if occ:
                has, first = True, occ[0]
                m = min(m, first + 1)
        hit = has and first < m
        if not live[b]:
            m, hit = 0, False
        ms.append(m)
        hits.append(hit)
    return np.asarray(ms, np.int32), np.asarray(hits, bool)


@settings(**SET)
@given(data=st.data(), b=st.integers(1, 4), c=st.integers(2, 6),
       use_eos=st.booleans())
def test_speculative_acceptance_matches_oracle(data, b, c, use_eos):
    """The fused acceptance rule == the obvious sequential oracle, and
    its safety invariants hold for ANY draft/sample/budget/eos draw:
    live rows always commit >= 1 token (progress), never more than
    ``max(budget, 1)`` (window safety), the committed prefix really is
    verify-exact, and dead rows commit nothing."""
    from repro.models.api import speculative_acceptance
    tok = st.integers(0, 3)                      # tiny vocab: real matches
    feed = np.asarray(data.draw(
        st.lists(st.lists(tok, min_size=c, max_size=c),
                 min_size=b, max_size=b)), np.int32)
    samples = np.asarray(data.draw(
        st.lists(st.lists(tok, min_size=c, max_size=c),
                 min_size=b, max_size=b)), np.int32)
    budget = np.asarray(data.draw(
        st.lists(st.integers(-2, 8), min_size=b, max_size=b)), np.int32)
    live = np.asarray(data.draw(
        st.lists(st.booleans(), min_size=b, max_size=b)), bool)
    eos = np.asarray(data.draw(
        st.lists(st.integers(-1, 3), min_size=b, max_size=b)),
        np.int32) if use_eos else None

    m, hit = speculative_acceptance(
        jnp.asarray(feed), jnp.asarray(samples), jnp.asarray(budget),
        jnp.asarray(live),
        None if eos is None else jnp.asarray(eos))
    m, hit = np.asarray(m), np.asarray(hit)
    m_ref, hit_ref = _acceptance_reference(feed, samples, budget, live,
                                           eos)
    np.testing.assert_array_equal(m, m_ref)
    np.testing.assert_array_equal(hit, hit_ref)
    for i in range(b):
        if not live[i]:
            assert m[i] == 0 and not hit[i]
            continue
        assert 1 <= m[i] <= max(budget[i], 1)    # progress, window-safe
        # verify-exactness of the committed prefix: every accepted draft
        # token equals the sample sequential decode would have emitted
        for j in range(m[i] - 1):
            assert feed[i, j + 1] == samples[i, j]
        if hit[i]:
            assert eos is not None and samples[i, m[i] - 1] == eos[i]


# ---------------------------------------------------------------------------
# TierStore: LRU / pin / demote safety under arbitrary op sequences
# ---------------------------------------------------------------------------


_OPS = st.lists(
    st.tuples(st.sampled_from(["put", "put_pin", "get", "pop", "pin",
                               "unpin"]),
              st.integers(0, 5)),                # key index
    min_size=1, max_size=30)


@settings(**SET)
@given(ops=_OPS, capacity=st.integers(0, 120), disk=st.booleans())
def test_tier_store_safety_under_arbitrary_ops(ops, capacity, disk,
                                               tmp_path_factory):
    """For ANY interleaving of put/get/pin/unpin/pop on a capacity-
    bounded store: pinned content is never lost; with a disk tier no
    un-popped content is EVER lost (eviction demotes, it does not
    drop); RAM occupancy accounting stays exact and within capacity
    unless a survivor has an excuse (pinned with nowhere to demote to,
    or the reference a get() just promoted); hits return the key's
    content.  The store is content-addressed — a key DETERMINES its
    bytes — so the model derives each blob from its key."""
    from repro.serving.tier_store import Blob, TierStore

    spill = str(tmp_path_factory.mktemp("spill")) if disk else None
    store = TierStore(capacity_bytes=capacity, spill_dir=spill)
    content = set()                              # keys put and not popped
    pins = {}                                    # key -> pin count
    keys = [bytes([i]) * 8 for i in range(6)]

    def blob_for(ki):
        return Blob({"x": np.full((10 * ki + 5,), ki + 1, np.uint8)})

    # keys a get() promoted (or touched) since the last eviction pass:
    # a promotion may leave its entry over capacity (the caller holds a
    # live reference), and non-evicting ops (pop/pin) don't clear it
    promoted = set()
    for op, ki in ops:
        key = keys[ki]
        if op in ("put", "put_pin"):
            store.put(key, blob_for(ki), pin=(op == "put_pin"))
            content.add(key)
            promoted.clear()                     # put ran an eviction pass
            if op == "put_pin":
                pins[key] = pins.get(key, 0) + 1
        elif op == "get":
            blob = store.get(key)
            if blob is not None:
                promoted.add(key)
            if key not in content:
                assert blob is None, "content fabricated from nowhere"
            elif disk or key in pins:
                # a disk tier never loses, a pin is never dropped; an
                # UNPINNED ram-only entry may legitimately have been
                # evicted, so only these two cases guarantee a hit
                assert blob is not None, "resident content lost"
            if blob is not None:
                assert int(blob.arrays["x"][0]) == ki + 1, \
                    "content does not match its key"
        elif op == "pop":
            store.pop(key)
            content.discard(key)
            pins.pop(key, None)
        elif op == "pin":
            if key in store:
                store.pin(key)
                pins[key] = pins.get(key, 0) + 1
        elif op == "unpin":
            if pins.get(key):
                store.unpin(key)
                pins[key] -= 1
                if not pins[key]:
                    del pins[key]
                    promoted.clear()             # unpin ran an eviction pass
        # -- invariants after EVERY op ----------------------------------
        assert store.occupancy_bytes == sum(
            b.nbytes for b in store._ram.values()), "byte accounting drifted"
        if store.occupancy_bytes > capacity:
            # eviction's post-condition: anything still resident over
            # capacity is either pinned with no disk tier to demote to,
            # or was promoted by a get() since the last eviction pass
            # (the caller's reference is live)
            for k in store._ram:
                assert (k in store._pins and not disk) or k in promoted, \
                    "over capacity without an excuse"
        for k in pins:
            assert k in store, "pinned content was dropped"
        if disk:
            for k in content:
                assert k in store, "disk-tiered store lost un-popped content"
    # drain: every key the model still holds is retrievable with its
    # content (LRU evictions only ever dropped UNPINNED RAM-only
    # entries, which the model tracked above)
    for k in content:
        if disk or k in pins:
            blob = store.get(k)
            assert blob is not None
            assert int(blob.arrays["x"][0]) == k[0] + 1

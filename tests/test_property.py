"""Property-based tests (hypothesis) on the system's invariants.

``hypothesis`` is an OPTIONAL dev dependency (see pyproject.toml): when
it is not installed this module skips instead of breaking collection of
the whole suite.  CI installs it so these tests always run there.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import ModelConfig, TConstConfig
from repro.core import tconst as T
from repro.kernels.xla_flash import flash_attention
from repro.layers import attention as A
from repro.layers import moe as M
from repro.data import tokenizer

SET = dict(max_examples=12, deadline=None)


@settings(**SET)
@given(lq=st.integers(1, 24), lk=st.integers(1, 24),
       qb=st.sampled_from([4, 8, 16]), kb=st.sampled_from([4, 8, 16]),
       causal=st.booleans(), window_raw=st.sampled_from([0, 3, 8]))
def test_flash_equals_naive_for_any_blocking(lq, lk, qb, kb, causal,
                                             window_raw):
    """Block sizes are an implementation detail: any (qb, kb) must give the
    same output as the naive reference.  (window implies causal in this
    framework, so the non-causal draws drop the window.)"""
    window = window_raw if causal else 0
    key = jax.random.PRNGKey(lq * 31 + lk)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, lq, 2, 8))
    k = jax.random.normal(ks[1], (1, lk, 2, 8))
    v = jax.random.normal(ks[2], (1, lk, 2, 8))
    qp = jnp.arange(lk - lq, lk, dtype=jnp.int32)    # queries at the end
    kp = jnp.arange(lk, dtype=jnp.int32)
    o = flash_attention(q, k, v, qp, kp, window, causal, 0.0, qb, kb)
    mode = "sliding" if window else ("causal" if causal else "full")
    mask = A.make_mask(qp, kp, mode, window)
    o_ref = A.sdpa(q, k, v, mask)
    valid = np.asarray(jnp.isfinite(o_ref)).all()
    assert valid
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4)


@settings(**SET)
@given(st.integers(0, 2**31 - 1))
def test_attention_rows_are_convex_combinations(seed):
    """Softmax attention output lies in the convex hull of V rows: its
    per-dim values are bounded by V's min/max."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 6, 2, 8)) * 3
    k = jax.random.normal(ks[1], (1, 9, 2, 8))
    v = jax.random.normal(ks[2], (1, 9, 2, 8))
    o = A.sdpa(q, k, v, None)
    vmin = jnp.min(v, axis=1, keepdims=True)
    vmax = jnp.max(v, axis=1, keepdims=True)
    # GQA grouping: compare per kv-head group
    og = o.reshape(1, 6, 2, 1, 8)
    assert bool(jnp.all(og <= vmax[:, :, :, None] + 1e-5))
    assert bool(jnp.all(og >= vmin[:, :, :, None] - 1e-5))


@settings(**SET)
@given(w_oh=st.sampled_from([4, 8]), w_og=st.sampled_from([4, 8]),
       h=st.integers(0, 2), nchunks=st.integers(1, 3))
def test_tconst_cache_constant_for_any_window_config(w_oh, w_og, h,
                                                     nchunks):
    cfg = ModelConfig(d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                      vocab_size=61, n_layers=(h + 2), dtype="float32",
                      attention_mode="tconst",
                      tconst=TConstConfig(w_oh=w_oh, w_og=w_og, h=h))
    c_small = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 64))
    c_large = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 8192))
    assert c_small == c_large


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), top_k=st.integers(1, 3))
def test_moe_combine_weights_sum_to_at_most_one(seed, top_k):
    """Per token, the (renormalised, possibly capacity-dropped) combine
    weights sum to <= 1, and == 1 when nothing is dropped."""
    logits = jax.random.normal(jax.random.PRNGKey(seed), (32, 4))
    dispatch, combine, _ = M.route_topk(logits, top_k, capacity=32)
    sums = np.asarray(jnp.sum(combine, axis=(1, 2)))
    np.testing.assert_allclose(sums, 1.0, atol=1e-5)   # no drops: exact
    _, combine2, _ = M.route_topk(logits, top_k, capacity=4)
    sums2 = np.asarray(jnp.sum(combine2, axis=(1, 2)))
    assert (sums2 <= 1.0 + 1e-5).all()


@settings(**SET)
@given(st.text(max_size=200))
def test_tokenizer_roundtrip(s):
    ids = tokenizer.encode(s)
    assert tokenizer.decode(ids) == s


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_decode_attend_is_permutation_invariant_in_dead_slots(seed):
    """Values in cache slots beyond valid_len must not affect output."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    from repro.kernels.ref import decode_reference
    q = jax.random.normal(ks[0], (2, 4, 16))
    k = jax.random.normal(ks[1], (2, 12, 2, 16))
    v = jax.random.normal(ks[2], (2, 12, 2, 16))
    vl = jnp.array([5, 9])
    o1 = decode_reference(q, k, v, vl)
    noise = jax.random.normal(ks[3], (2, 12, 2, 16)) * 100
    slot = jnp.arange(12)[None, :, None, None]
    k2 = jnp.where(slot >= vl[:, None, None, None], k + noise, k)
    v2 = jnp.where(slot >= vl[:, None, None, None], v + noise, v)
    o2 = decode_reference(q, k2, v2, vl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)

"""Sharding rules: spec assignment is total, divisibility-safe, and
matches the documented policy (runs on 1 device via eval_shape — no mesh
entry needed for spec computation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_config, get_shape
from repro.models.api import build_model
from repro.sharding import rules


class FakeMesh:
    """Just enough Mesh surface for the rule functions."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    def __repr__(self):
        return f"FakeMesh({self.shape})"


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _specs(tree, mesh, fsdp=False):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {(rules._leaf_name(p) + ":" + "/".join(
        str(getattr(q, "key", getattr(q, "idx", q))) for q in p)):
        rules._spec_for_param(p, l, mesh, fsdp) for p, l in flat}


@pytest.mark.parametrize("arch", ["llama3_405b", "mixtral_8x22b",
                                  "mamba2_130m", "gemma3_4b",
                                  "deepseek_moe_16b", "whisper_small"])
def test_every_param_gets_a_valid_spec(arch):
    cfg = get_config(arch)
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    for mesh in (MESH1, MESH2):
        flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
        for path, leaf in flat:
            spec = rules._spec_for_param(path, leaf, mesh, fsdp=True)
            assert len(spec) <= leaf.ndim
            # every sharded dim must divide evenly
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)


def test_llama_policy_examples():
    cfg = get_config("llama3_405b")
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = _specs(shapes, MESH1)
    wq = next(v for k, v in specs.items() if k.startswith("wq:"))
    assert "model" in wq                        # 128 heads shard over model
    tok = next(v for k, v in specs.items() if k.startswith("tok:"))
    assert tok[0] == "model"                    # vocab-sharded embedding


def test_moe_expert_parallel_when_divisible():
    # deepseek: 64 experts % 16 == 0 -> expert-parallel
    cfg = get_config("deepseek_moe_16b")
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    specs = _specs(shapes, MESH1)
    moe_gate = [v for k, v in specs.items()
                if k.startswith("w_gate:") and "layers" in k and
                "shared" not in k]
    assert any(s[1] == "model" for s in moe_gate)   # (L, E, d, f): E dim
    # mixtral: 8 experts < 16 -> fall back to ffn-dim sharding
    cfg2 = get_config("mixtral_8x22b")
    shapes2 = jax.eval_shape(build_model(cfg2).init, jax.random.PRNGKey(0))
    specs2 = _specs(shapes2, MESH1)
    g2 = [v for k, v in specs2.items()
          if k.startswith("w_gate:") and "shared" not in k]
    assert all(s[1] != "model" for s in g2)
    assert any("model" in s for s in g2)


def test_batch_shardings_small_batch_never_oversharded():
    """On a 1x1 mesh any spec is fine (axis size 1 == replicate); the real
    policy decision (B=1 < dsize -> replicate) is what we check."""
    import jax.sharding as js
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(js.AxisType.Auto,) * 2)
    specs = {"tokens": jax.ShapeDtypeStruct((1, 1024), jnp.int32)}
    sh = rules.batch_shardings(specs, mesh)
    assert sh["tokens"].is_fully_replicated    # size-1 axes == replicated
    # policy check against a 16-wide data axis (no devices needed)
    assert not (1 % 16 == 0 and 1 >= 16)       # guard in batch_shardings


def test_cache_specs_long_context_seq_sharding():
    """B=1 long-context cache shards its sequence dim over data."""
    k = jax.ShapeDtypeStruct((24, 1, 32768, 8, 128), jnp.bfloat16)
    spec = rules._cache_spec(
        (jax.tree_util.DictKey("k"),), k, _RealMesh(), batch=1)
    assert spec[2] is not None                   # seq dim sharded


class _RealMesh(FakeMesh):
    def __init__(self):
        super().__init__({"data": 16, "model": 16})


def test_shard_act_noop_without_context():
    rules.set_activation_context(None)
    x = jnp.ones((4, 8, 16))
    y = rules.shard_act(x)
    assert y is x

"""Layout-native decode (KVView) parity and regression suite.

Three layers of checks:

1. **Kernel units** — the paged decode-attention implementations (Pallas
   interpret-mode page-table walk, page-at-a-time XLA fallback) and the
   fused int8 decode kernel against the dense pure-jnp oracle, swept
   over shapes / windows / quantisation.
2. **Dense-oracle parity** — for every family (tconst-tlin, dense LM,
   enc-dec) x layout (paged, int8, paged+int8): a staggered-phase decode
   chunk where every layout-native ``step`` is compared against the
   legacy dense-dict step run on ``DecodeState.merged()``.  Exact
   layouts (paged fp32) must match to float-associativity noise with
   identical argmax; int8 layouts are bounded by the symmetric-int8
   rounding of the one vector that is quantized-before-attend (the
   legacy path attended the f32 vector and quantized on repack).
3. **Regressions** — under ``--layout paged`` a decode ``step`` contains
   ZERO intermediates with the dense ``slots x max_len`` logical KV
   shape (the per-step densification this refactor retires), and the
   compacted resync lowers without a ``while`` loop (all pending rows
   sync in one batched dispatch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.config import get_config, reduced
from repro.core import tconst as TC
from repro.kernels.paged_decode_attention import (
    paged_decode_attention_pallas, paged_decode_attention_xla)
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels import ref as REF
from repro.models import encdec as ED
from repro.models import layouts as LT
from repro.models import lm as LM
from repro.models.api import build_decode, build_model
from repro.serving.engine import Engine
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session

KEY = jax.random.PRNGKey(11)

requires_tpu = pytest.mark.skipif(
    jax.default_backend() != "tpu",
    reason="compiled (non-interpret) Pallas kernels need a TPU backend; "
           "the pallas-interpret CI job covers them in interpret mode")


# ---------------------------------------------------------------------------
# Kernel units: paged walk + fused int8 vs the dense oracle
# ---------------------------------------------------------------------------


def _paged_case(B, S, H, KV, D, page, pool_extra=2, quant=False, seed=0):
    """Random pool + per-slot table + the equivalent dense cache."""
    pps = -(-S // page)
    pool_pages = B * pps + pool_extra
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    pool_k = jax.random.normal(ks[1], (pool_pages + 1, page, KV, D))
    pool_v = jax.random.normal(ks[2], (pool_pages + 1, page, KV, D))
    perm = jax.random.permutation(ks[3], pool_pages)[:B * pps]
    pt = perm.reshape(B, pps).astype(jnp.int32)
    vl = jnp.asarray(np.random.default_rng(seed).integers(1, S + 1, B),
                     jnp.int32)
    kw = {}
    if quant:
        pool_k, ksc = LT.quantize_int8(pool_k)
        pool_v, vsc = LT.quantize_int8(pool_v)
        kw = dict(k_scale=ksc, v_scale=vsc)
    # dense logical view for the oracle
    dk = jnp.take(pool_k if not quant else
                  LT.dequantize_int8(pool_k, kw["k_scale"], jnp.float32),
                  pt, axis=0).reshape(B, pps * page, KV, D)[:, :S]
    dv = jnp.take(pool_v if not quant else
                  LT.dequantize_int8(pool_v, kw["v_scale"], jnp.float32),
                  pt, axis=0).reshape(B, pps * page, KV, D)[:, :S]
    return q, pool_k, pool_v, pt, vl, kw, dk, dv


@pytest.mark.parametrize("B,S,H,KV,D,page,win", [
    (2, 64, 4, 2, 32, 16, 0),
    (3, 96, 6, 3, 32, 32, 0),
    (2, 128, 8, 8, 64, 32, 24),      # sliding window
    (1, 48, 4, 1, 16, 16, 0),        # padded last page (48 = 3 pages)
])
@pytest.mark.parametrize("quant", [False, True])
def test_paged_xla_fallback_vs_dense_oracle(B, S, H, KV, D, page, win,
                                            quant):
    """The page-walk fallback against the dense oracle: the oracle sees
    the IDENTICAL logical values (paging is exact; the int8 case
    dequantises the same int8+scale data), so only float-associativity
    noise separates them."""
    q, pk, pv, pt, vl, kw, dk, dv = _paged_case(B, S, H, KV, D, page,
                                                quant=quant)
    o = paged_decode_attention_xla(q, pk, pv, pt, vl, window=win, **kw)
    slots = jnp.arange(dk.shape[1])[None]
    keep = slots < vl[:, None]
    if win:
        keep = jnp.logical_and(keep, slots >= vl[:, None] - win)
    o_ref = _masked_decode_reference(q, dk, dv, keep)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=3e-5)


def _masked_decode_reference(q, k, v, keep):
    """decode_reference with an arbitrary (B, S) validity mask."""
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg * (D ** -0.5),
                   k.astype(jnp.float32))
    s = jnp.where(keep[:, None, None, :], s, -2.3819763e38)
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mx) * keep[:, None, None, :]
    p = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


@pytest.mark.parametrize("B,S,H,KV,D,page,win", [
    (2, 64, 4, 2, 32, 16, 0),
    (2, 96, 4, 2, 32, 32, 16),
])
@pytest.mark.parametrize("quant", [False, True])
def test_paged_pallas_interpret_matches_xla_fallback(B, S, H, KV, D, page,
                                                     win, quant):
    """The Pallas page-walk kernel (interpret mode: same arithmetic as on
    TPU) must agree with the XLA fallback — one contract, two backends."""
    q, pk, pv, pt, vl, kw, _, _ = _paged_case(B, S, H, KV, D, page,
                                              quant=quant)
    o_xla = paged_decode_attention_xla(q, pk, pv, pt, vl, window=win, **kw)
    o_pls = paged_decode_attention_pallas(q, pk, pv, pt, vl, window=win,
                                          interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(o_pls), np.asarray(o_xla),
                               atol=1e-5)


def test_int8_fused_decode_kernel_vs_dequant_oracle():
    B, S, H, KV, D = 2, 64, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    kq, ksc = LT.quantize_int8(k)
    vq, vsc = LT.quantize_int8(v)
    vl = jnp.array([17, 64], jnp.int32)
    o = decode_attention_pallas(q, kq, vq, vl, k_scale=ksc, v_scale=vsc,
                                interpret=True)
    o_ref = REF.decode_reference(
        q, LT.dequantize_int8(kq, ksc, jnp.float32),
        LT.dequantize_int8(vq, vsc, jnp.float32), vl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


@requires_tpu
def test_paged_kernel_compiled_on_tpu():
    """Compiled (non-interpret) path — exercised only where a TPU exists
    so failures surface as SKIPPED with a reason, never a silent pass."""
    q, pk, pv, pt, vl, kw, dk, dv = _paged_case(2, 64, 4, 2, 32, 16)
    o = paged_decode_attention_pallas(q, pk, pv, pt, vl, interpret=False)
    o_ref = REF.decode_reference(q, dk, dv, vl)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-3)


# ---------------------------------------------------------------------------
# Dense-oracle parity: staggered decode chunk, every family x layout
# ---------------------------------------------------------------------------


def _tconst_family():
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode="tlin")

    def oracle(params, cache, tok):
        rows = TC.pending_resync_rows(cache, cfg)
        cache = TC.resync_rows_compacted(params, cache, cfg, rows, "tlin")
        return TC.decode_step(params, cache, tok, cfg, mode="tlin")
    return cfg, oracle, {}


def _lm_family():
    cfg = reduced(get_config("llama3_405b"), dtype="float32")
    return cfg, (lambda p, c, t: LM.lm_decode_step(p, c, t, cfg)), {}


def _encdec_family():
    cfg = reduced(get_config("whisper_small"), dtype="float32")
    extras = lambda: {"audio_feats": jnp.zeros(  # noqa: E731
        (cfg.encoder_seq, cfg.frontend_dim), jnp.float32)}
    return cfg, (lambda p, c, t: ED.encdec_decode_step(p, c, t, cfg)), \
        {"extras": extras}


FAMILIES = {"tlin": _tconst_family, "lm": _lm_family, "encdec": _encdec_family}
LAYOUTS = {
    # (spec, logits atol vs the merged() oracle, argmax must match)
    # int8 bound: the step quantizes the NEW token's K/V before attending
    # (the legacy path attended it in f32 and quantized on repack), so
    # logits carry one vector's symmetric-int8 rounding (~0.4% of its
    # max magnitude) — the documented lossy-layout tolerance.
    "paged": (LT.LayoutSpec(kind="paged", page_size=16), 2e-5, True),
    "int8": (LT.LayoutSpec(kind="int8"), 2e-2, False),
    "paged_int8": (LT.LayoutSpec(kind="paged_int8", page_size=16), 2e-2,
                   False),
}


@pytest.fixture(scope="module", params=sorted(FAMILIES))
def family(request):
    cfg, oracle, kw = FAMILIES[request.param]()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return request.param, cfg, api, params, oracle, kw


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_layout_native_step_matches_merged_oracle(family, layout):
    """Every layout-native fused ``step`` of a STAGGERED two-slot decode
    (different prompt lengths => different phases, tconst rows crossing
    the W_og resync boundary at different steps) must match the legacy
    dense-dict step run on the same state's ``merged()`` oracle."""
    name, cfg, api, params, oracle, kw = family
    spec, tol, exact_argmax = LAYOUTS[layout]
    dec = build_decode(cfg, spec)
    state = dec.init_state(2, 96)
    extras = kw.get("extras", lambda: None)
    prompts = [(np.arange(1, 10) % cfg.vocab_size).astype(np.int32),
               ((np.arange(1, 14) * 7) % cfg.vocab_size).astype(np.int32)]
    tok = []
    for slot, p in enumerate(prompts):
        lg, state = dec.prefill_into_slot(params, state, jnp.int32(slot),
                                          jnp.asarray(p), extras=extras())
        tok.append(int(jnp.argmax(lg)))
    tok = jnp.asarray(tok, jnp.int32)

    step = jax.jit(dec.step)
    for t in range(10):
        lg_o, _ = oracle(params, state.merged(), tok)
        lg, state = step(params, state, tok)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_o),
                                   atol=tol, err_msg=f"{name}/{layout}@{t}")
        if exact_argmax:
            np.testing.assert_array_equal(
                np.asarray(jnp.argmax(lg, -1)),
                np.asarray(jnp.argmax(jnp.asarray(lg_o), -1)))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


def test_pallas_interpret_full_model_matches_xla_fallback(family,
                                                          monkeypatch):
    """Flipping the runtime flags routes the SAME step through the Pallas
    interpret kernels; logits must agree with the XLA fallback path."""
    name, cfg, api, params, oracle, kw = family
    dec = build_decode(cfg, LT.LayoutSpec(kind="paged_int8", page_size=16))
    state = dec.init_state(2, 96)
    extras = kw.get("extras", lambda: None)
    p = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    _, state = dec.prefill_into_slot(params, state, jnp.int32(0),
                                     jnp.asarray(p), extras=extras())
    tok = jnp.array([3, 5], jnp.int32)
    lg_xla, _ = dec.raw_step(params, state, tok)
    monkeypatch.setattr(runtime.flags, "use_pallas", True)
    monkeypatch.setattr(runtime.flags, "pallas_interpret", True)
    lg_pls, _ = dec.raw_step(params, state, tok)
    np.testing.assert_allclose(np.asarray(lg_pls), np.asarray(lg_xla),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# Regressions: densification retired, resync batched
# ---------------------------------------------------------------------------


def _collect_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                acc.add(tuple(v.aval.shape))
        for p in eqn.params.values():
            stack = [p]
            while stack:
                x = stack.pop()
                if isinstance(x, jax.core.ClosedJaxpr):
                    _collect_shapes(x.jaxpr, acc)
                elif isinstance(x, jax.core.Jaxpr):
                    _collect_shapes(x, acc)
                elif isinstance(x, (list, tuple)):
                    stack.extend(x)
    return acc


def _banned_dense_shapes(state, length_axes):
    dense = {tuple(s.shape) for k, s in state.dense_shapes().items()
             if k in length_axes}
    return dense | {s[1:] for s in dense}        # full + per-layer slice


def test_paged_lm_step_never_materializes_dense_kv():
    """Acceptance criterion: under ``--layout paged`` a decode ``step``
    performs ZERO dense ``slots x max_len`` KV materialisation — no
    intermediate in its jaxpr has the dense logical KV shape (full or
    per-layer).  The dense layout's own step DOES (control, so the
    check has teeth)."""
    cfg = reduced(get_config("llama3_405b"), dtype="float32")
    api = build_model(cfg)
    params_s = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    tok_s = jax.ShapeDtypeStruct((4,), jnp.int32)

    dec = build_decode(cfg, LT.LayoutSpec(kind="paged", page_size=16,
                                          pool_pages=10))
    state_s = jax.eval_shape(lambda: dec.init_state(4, 128))
    shapes = _collect_shapes(
        jax.make_jaxpr(dec.step)(params_s, state_s, tok_s).jaxpr, set())
    banned = _banned_dense_shapes(state_s, LM.LENGTH_AXES)
    assert not (banned & shapes), banned & shapes

    ctrl = build_decode(cfg, "dense")
    ctrl_state = jax.eval_shape(lambda: ctrl.init_state(4, 128))
    ctrl_shapes = _collect_shapes(
        jax.make_jaxpr(ctrl.step)(params_s, ctrl_state, tok_s).jaxpr, set())
    assert banned & ctrl_shapes      # the dense step does carry the shape


def test_paged_tlin_hit_step_never_materializes_dense_hist():
    """Same property for TLinFormer's O(N) history KV on the cache-HIT
    path (``raw_step``; the miss path is O(N) by definition)."""
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode="tlin")
    api = build_model(cfg)
    params_s = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    dec = build_decode(cfg, LT.LayoutSpec(kind="paged", page_size=16,
                                          pool_pages=10))
    state_s = jax.eval_shape(lambda: dec.init_state(4, 128))
    tok_s = jax.ShapeDtypeStruct((4,), jnp.int32)
    shapes = _collect_shapes(
        jax.make_jaxpr(dec.raw_step)(params_s, state_s, tok_s).jaxpr, set())
    banned = _banned_dense_shapes(state_s, TC.LENGTH_AXES)
    assert not (banned & shapes), banned & shapes


def _has_primitive(jaxpr, name):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            return True
        for p in eqn.params.values():
            stack = [p]
            while stack:
                x = stack.pop()
                if isinstance(x, jax.core.ClosedJaxpr):
                    x = x.jaxpr
                if isinstance(x, jax.core.Jaxpr):
                    if _has_primitive(x, name):
                        return True
                elif isinstance(x, (list, tuple)):
                    stack.extend(x)
    return False


def test_compacted_resync_is_single_dispatch_not_while_loop():
    """Satellite: the compacted resync batches the gather/scatter over
    all pending rows — its jaxpr holds a ``cond``/``switch``, never the
    PR-2 per-row ``while`` loop."""
    cfg = reduced(get_config("tconst_41m"), dtype="float32")
    api = build_model(cfg)
    params_s = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    cache_s = jax.eval_shape(
        lambda: TC.init_tconst_cache(cfg, 4, 64, "tconst"))
    rows_s = jax.ShapeDtypeStruct((4,), jnp.bool_)
    closed = jax.make_jaxpr(
        lambda p, c, r: TC.resync_rows_compacted(p, c, cfg, r))(
        params_s, cache_s, rows_s)
    assert not _has_primitive(closed.jaxpr, "while")


def test_resync_buckets_cover_all_counts():
    for b in (1, 2, 3, 4, 5, 8, 13):
        buckets = TC.resync_buckets(b)
        assert buckets[0] == 0 and buckets[-1] == b
        for count in range(b + 1):
            k = buckets[int(np.searchsorted(np.asarray(buckets), count))]
            assert count <= k <= max(2 * count, buckets[1] if count else 0)


# ---------------------------------------------------------------------------
# Serving-level: paged_int8 end-to-end + byte accounting
# ---------------------------------------------------------------------------


def test_paged_int8_scheduler_sessions_complete_and_shrink_kv():
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode="tlin")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    pb = ((np.arange(1, 14) * 7) % cfg.vocab_size).astype(np.int32)
    spec = LT.LayoutSpec(kind="paged_int8", page_size=16, pool_pages=10)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                          max_len=128, chunk_size=4)
    sa = sched.submit(Session(pa, max_new_tokens=12))
    sched.step()
    sb = sched.submit(Session(pb, max_new_tokens=9))
    sched.run()
    assert sa.done and len(sa.tokens) == 12
    assert sb.done and len(sb.tokens) == 9
    assert len(sched.free_pages) == 10           # pages recycled
    dense_bytes = SlotScheduler(api.decode, params, slots=2,
                                max_len=128).kv_bytes()
    # int8 pages + scales in an undersized pool: well under dense fp32
    assert sched.kv_bytes() < dense_bytes / 2


def test_step_view_bytes_accounting():
    """Per-step HBM bytes touched: the paged view counts only ASSIGNED
    pages (+ table), so it sits below the dense-logical bytes the
    retired ``merged()`` path would have materialised."""
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode="tlin")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    spec = LT.LayoutSpec(kind="paged", page_size=16, pool_pages=10)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                          max_len=128, chunk_size=4)
    pa = (np.arange(1, 10) % cfg.vocab_size).astype(np.int32)
    sched.submit(Session(pa, max_new_tokens=8))
    sched.step()
    state = sched.state
    assert state.step_view_bytes() < state.dense_logical_bytes()
    # dense layout: view bytes == logical bytes (identity layout)
    dstate = api.decode.init_state(2, 128)
    assert dstate.step_view_bytes() == dstate.dense_logical_bytes()


def test_engine_paged_int8_generates():
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode="tlin")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    out = Engine(api, params, max_len=96, layout="paged_int8").generate(
        {"tokens": jnp.ones((2, 9), jnp.int32)}, 12)
    assert out.shape == (2, 12) and (out >= 0).all()

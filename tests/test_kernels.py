"""Per-kernel correctness: Pallas (interpret=True) and XLA-blocked
implementations swept over shapes/dtypes against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as REF
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_fwd_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.xla_flash import INVALID_POS, flash_attention
from repro.layers.ssm import ssd_chunked

KEY = jax.random.PRNGKey(7)


def _qkv(B, Lq, Lk, H, KV, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Lq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Lk, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Lk, KV, D), jnp.float32).astype(dtype)
    return q, k, v


SHAPES = [
    # B, Lq, Lk, H, KV, D, causal, window, softcap
    (1, 64, 64, 4, 4, 32, True, 0, 0.0),
    (2, 64, 128, 8, 2, 64, True, 32, 0.0),
    (2, 128, 64, 4, 1, 32, False, 0, 0.0),
    (1, 128, 128, 8, 8, 128, True, 0, 20.0),
    (3, 96, 160, 6, 2, 32, True, 16, 0.0),   # non-pow2 everything
]


@pytest.mark.parametrize("B,Lq,Lk,H,KV,D,causal,win,cap", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_vs_oracle(B, Lq, Lk, H, KV, D, causal, win, cap,
                                dtype):
    if Lq % 32 or Lk % 32:
        pytest.skip("pallas path requires block-divisible shapes")
    q, k, v = _qkv(B, Lq, Lk, H, KV, D, dtype)
    qp = jnp.broadcast_to(jnp.arange(Lk - Lq, Lk), (B, Lq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(Lk), (B, Lk)).astype(jnp.int32)
    o = flash_attention_fwd_pallas(q, k, v, qp, kp, causal=causal,
                                   window=win, softcap=cap, block_q=32,
                                   block_k=32, interpret=True)
    o_ref = REF.mha_reference(q, k, v, qp, kp, window=win, causal=causal,
                              softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,Lq,Lk,H,KV,D,causal,win,cap", SHAPES)
def test_xla_flash_vs_oracle(B, Lq, Lk, H, KV, D, causal, win, cap):
    q, k, v = _qkv(B, Lq, Lk, H, KV, D, jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(Lk - Lq, Lk), (B, Lq)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(Lk), (B, Lk)).astype(jnp.int32)
    kp = kp.at[:, -3:].set(INVALID_POS)      # dead cache slots
    o = flash_attention(q, k, v, qp, kp, win, causal, cap, 32, 64)
    o_ref = REF.mha_reference(q, k, v, qp, kp, window=win, causal=causal,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5)


def test_xla_flash_shared_positions_match_batched():
    B, L, H, KV, D = 2, 80, 4, 2, 32
    q, k, v = _qkv(B, L, L, H, KV, D, jnp.float32)
    p1 = jnp.arange(L, dtype=jnp.int32)
    pB = jnp.broadcast_to(p1, (B, L))
    o1 = flash_attention(q, k, v, p1, p1, 16, True, 0.0, 32, 32)
    oB = flash_attention(q, k, v, pB, pB, 16, True, 0.0, 32, 32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(oB), atol=1e-6)


def test_xla_flash_grads_vs_oracle():
    B, L, H, KV, D = 2, 48, 4, 2, 16
    q, k, v = _qkv(B, L, L, H, KV, D, jnp.float32)
    pos = jnp.arange(L, dtype=jnp.int32)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(
            flash_attention(q, k, v, pos, pos, 8, True, 4.0, 16, 16)))

    def f_ref(q, k, v):
        pb = jnp.broadcast_to(pos, (B, L))
        return jnp.sum(jnp.sin(REF.mha_reference(
            q, k, v, pb, pb, window=8, causal=True, softcap=4.0)))

    g1 = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


@pytest.mark.parametrize("B,S,H,KV,D", [
    (2, 64, 8, 2, 32), (4, 256, 12, 12, 64), (1, 128, 4, 1, 128),
    (3, 96, 6, 3, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_decode_vs_oracle(B, S, H, KV, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32).astype(dtype)
    vl = jnp.asarray(np.random.default_rng(0).integers(1, S, B), jnp.int32)
    o = decode_attention_pallas(q, k, v, vl, interpret=True)
    o_ref = REF.decode_reference(q, k, v, vl)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


@pytest.mark.parametrize("Bt,L,H,P,N,chunk", [
    (2, 64, 4, 16, 32, 16), (1, 32, 2, 8, 16, 8), (2, 128, 8, 32, 64, 32),
])
def test_pallas_ssd_vs_chunked_oracle(Bt, L, H, P, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (Bt, L, N))
    c = jax.random.normal(ks[4], (Bt, L, N))
    init = jax.random.normal(KEY, (Bt, H, P, N))
    y1, s1 = ssd_scan_pallas(x, dt, a, b, c, chunk, init_state=init,
                             interpret=True)
    y2, s2 = ssd_chunked(x, dt, a, b, c, chunk, init_state=init)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_ssd_chunked_matches_stepwise_recurrence():
    from repro.layers.ssm import ssd_step
    Bt, L, H, P, N = 2, 24, 3, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (Bt, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (Bt, L, N))
    c = jax.random.normal(ks[4], (Bt, L, N))
    st = jnp.zeros((Bt, H, P, N))
    ys = []
    for t in range(L):
        y, st = ssd_step(st, x[:, t], dt[:, t], a, b[:, t], c[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, 1)
    y_chk, st_chk = ssd_chunked(x, dt, a, b, c, chunk=8)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_chk), atol=2e-4)

"""Shared stream-identity / parity harness for the serving suites.

Stream identity is THE serving invariant: every serving-layer feature —
chunked admission, prefix-sharing CoW, spill/resume tiering, mesh
sharding, speculative decoding — must change WALL-CLOCK only, never a
token.  Before PR 10 each suite re-implemented the same scaffolding
(family fixtures, layout specs, the scheduler driver, the stream
comparison); this module is the single copy they all import, so a new
serving feature gets its {family} x {layout} parity matrix by calling
:func:`stream_parity_case` with one kwargs delta instead of cloning a
hundred lines.

Building blocks:

* :func:`family` — cached ``(cfg, api, params)`` per model family.  One
  build per pytest process, shared across every suite that imports it.
* :func:`layout_spec` — ``kind`` string -> LayoutSpec (None for dense).
* :func:`serve_streams` — the canonical scheduler driver: submit
  prompts (optionally staggered), run to completion, return the token
  streams (+ the scheduler, for stats assertions).
* :func:`stream_parity_case` — the matrix runner: serve the SAME
  prompts under a baseline and a variant scheduler configuration and
  assert token-identical streams.
* :func:`assert_read_slot_matches_merged` — the ``merged()``-oracle
  check: a slot's ``read_slot`` row must equal the dense-logical oracle
  for every field, every layout (int8: both sides dequantize the same
  stored values).

Deliberately NOT a conftest: plain importable module (pytest's default
prepend import mode puts ``tests/`` on ``sys.path``), so helpers stay
grep-able and usable from scripts.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.config import get_config, reduced
from repro.models import layouts as LT
from repro.models.api import build_decode, build_model
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session

PAGE = 16

# family -> (registry arch, config overrides).  "lm" is the small dense
# GQA model; "lm_mqa" the 1-KV-head reduction the tiering/CoW suites use
# (MQA exercises the kv-head-replicated layout paths).
FAMILY_ARCHS: Dict[str, Tuple[str, Dict]] = {
    "tconst": ("tconst_41m", {}),
    "tlin": ("tconst_41m", {"attention_mode": "tlin"}),
    "lm": ("smollm_360m", {}),
    "lm_mqa": ("llama3_405b", {}),
    "encdec": ("whisper_small", {}),
}


@functools.lru_cache(maxsize=None)
def family(name: str):
    """(cfg, api, params) for a named family — built once per process
    and shared by every suite that imports this module."""
    arch, kw = FAMILY_ARCHS[name]
    cfg = reduced(get_config(arch), dtype="float32", **kw)
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


def layout_spec(kind: str, page_size: int = PAGE,
                pool_pages: Optional[int] = 24):
    """LayoutSpec for a matrix ``kind`` string; dense -> None (the
    build_decode default)."""
    if kind == "dense":
        return None
    return LT.LayoutSpec(kind=kind, page_size=page_size,
                         pool_pages=pool_pages)


def extras_for(cfg, seed: int = 9):
    """Per-session extras a family's prefill needs (encdec: audio)."""
    if not cfg.is_encdec:
        return None
    rng = np.random.RandomState(seed)
    return {"audio_feats": rng.randn(
        cfg.encoder_seq, cfg.frontend_dim).astype(np.float32)}


def make_prompts(cfg, lens: Sequence[int], seed: int = 3) -> List:
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def shared_prompts(cfg, n: int, common_len: int = 48, tail_len: int = 8,
                   seed: int = 0) -> List:
    """n prompts sharing a page-aligned common prefix, distinct equal-
    length tails (equal lengths keep prefill bitwise-reproducible, so
    greedy parity with solo runs is exact)."""
    rng = np.random.RandomState(seed)
    common = rng.randint(1, cfg.vocab_size,
                         size=common_len).astype(np.int32)
    return [np.concatenate([common, rng.randint(
        1, cfg.vocab_size, size=tail_len).astype(np.int32)])
        for _ in range(n)]


def serve_streams(cfg, params, prompts, spec=None, *, gen: int = 6,
                  stagger: bool = True, slots: int = 2,
                  max_len: int = 128, chunk_size: int = 4,
                  prefill_chunk: Optional[int] = None,
                  session_kw: Optional[Dict] = None,
                  mesh=None, **sched_kw):
    """The canonical scheduler driver: submit every prompt (stepping
    once between submissions when ``stagger``, so slots sit at mixed
    resync phases), run to completion, return (streams, scheduler)."""
    sched = SlotScheduler(build_decode(cfg, spec, mesh=mesh), params,
                          slots=slots, max_len=max_len,
                          chunk_size=chunk_size,
                          prefill_chunk=prefill_chunk, **sched_kw)
    sessions = []
    for p in prompts:
        sessions.append(sched.submit(Session(
            p, max_new_tokens=gen, extras=extras_for(cfg),
            **(session_kw or {}))))
        if stagger:
            sched.step()
    sched.run()
    return [s.tokens for s in sessions], sched


def assert_streams_equal(ref, got, label: str = "") -> None:
    """Token-identical streams, with a per-session diff on failure."""
    assert len(ref) == len(got), \
        f"{label}: {len(ref)} vs {len(got)} sessions"
    for i, (r, g) in enumerate(zip(ref, got)):
        assert r == g, (f"{label}: session {i} stream diverged\n"
                        f"  ref: {r}\n  got: {g}")


def stream_parity_case(family_name: str, kind: str, *,
                       variant_kw: Dict, base_kw: Optional[Dict] = None,
                       prompt_lens: Sequence[int] = (21, 34, 17),
                       spec=None, seed: int = 3, label: str = "",
                       **common_kw):
    """The {family} x {layout} matrix runner: serve the same prompts
    under ``base_kw`` (default: the plain scheduler) and ``variant_kw``
    and assert the streams are token-identical.  Returns (streams,
    variant scheduler) for follow-up stats assertions."""
    cfg, api, params = family(family_name)
    prompts = make_prompts(cfg, prompt_lens, seed)
    spec = layout_spec(kind) if spec is None and kind != "dense" else spec
    ref, _ = serve_streams(cfg, params, prompts, spec,
                           **{**common_kw, **(base_kw or {})})
    out, sched = serve_streams(cfg, params, prompts, spec,
                               **{**common_kw, **variant_kw})
    assert_streams_equal(ref, out,
                         label or f"{family_name}/{kind}")
    return out, sched


def assert_read_slot_matches_merged(state, slot: int = 0) -> None:
    """``read_slot`` must equal the ``merged()`` dense-logical oracle's
    row for every field (int8 layouts: both sides dequantize the same
    stored values, so the comparison is still exact)."""
    row = jax.jit(state.read_slot)(np.int32(slot))
    oracle = state.merged()
    for f, v in row.items():
        ref = jax.lax.dynamic_slice_in_dim(oracle[f], slot, 1,
                                           state.axes[f])
        np.testing.assert_allclose(np.asarray(v), np.asarray(ref),
                                   rtol=0, atol=0,
                                   err_msg=f"read_slot({f}) != oracle")

"""Unit tests for the substrate layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig
from repro.layers import attention as A
from repro.layers import moe as M
from repro.layers import rope as R
from repro.layers.common import init_layernorm, init_rmsnorm, layernorm, \
    rmsnorm

KEY = jax.random.PRNGKey(0)


def test_rmsnorm_unit_scale():
    p = init_rmsnorm(64)
    x = jax.random.normal(KEY, (4, 64)) * 17.0
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_zero_mean():
    p = init_layernorm(64)
    x = jax.random.normal(KEY, (4, 64)) + 5.0
    y = layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)


def test_rope_is_rotation_norm_preserving():
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.arange(8)
    cos, sin = R.rope_cos_sin(pos, 32, 10000.0)
    y = R.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m-n."""
    d = 32
    q = jax.random.normal(KEY, (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))

    def dot_at(m, n):
        cq, sq = R.rope_cos_sin(jnp.array([m]), d, 10000.0)
        ck, sk = R.rope_cos_sin(jnp.array([n]), d, 10000.0)
        qr = R.apply_rope(q, cq, sq)
        kr = R.apply_rope(k, ck, sk)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(1007, 1000)) < 1e-4


def test_mrope_equals_rope_for_equal_streams():
    d = 32
    pos = jnp.arange(16)
    c1, s1 = R.rope_cos_sin(pos, d, 10000.0)
    p3 = R.text_positions3(pos)
    c3, s3 = R.mrope_cos_sin(p3, d, 10000.0, (8, 4, 4))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c3), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s3), atol=1e-6)


def test_sdpa_masked_rows_are_zero():
    q = jax.random.normal(KEY, (1, 4, 2, 8))
    k = jax.random.normal(KEY, (1, 6, 2, 8))
    v = jax.random.normal(KEY, (1, 6, 2, 8))
    mask = jnp.zeros((1, 4, 6), bool).at[:, 2:].set(True)
    o = A.sdpa(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o[:, :2]), 0.0, atol=1e-7)
    assert float(jnp.max(jnp.abs(o[:, 2:]))) > 0


def test_gqa_equals_mha_when_kv_repeated():
    B, L, H, D = 2, 10, 8, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, L, H, D))
    k2 = jax.random.normal(ks[1], (B, L, 2, D))
    v2 = jax.random.normal(ks[2], (B, L, 2, D))
    k8 = jnp.repeat(k2, 4, axis=2)
    v8 = jnp.repeat(v2, 4, axis=2)
    pos = jnp.arange(L)
    mask = A.make_mask(pos, pos, "causal")
    o_gqa = A.sdpa(q, k2, v2, mask)
    o_mha = A.sdpa(q, k8, v8, mask)
    np.testing.assert_allclose(np.asarray(o_gqa), np.asarray(o_mha),
                               atol=1e-5)


def test_decode_attend_incremental_equals_full():
    cfg = ModelConfig(d_model=64, n_heads=8, n_kv_heads=2)
    p = A.init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 64))
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    cos, sin = R.rope_cos_sin(pos, 8, 10000.0)
    full = A.attention_block(p, x, x, A.make_mask(pos, pos, "causal"),
                             cos, sin, cos, sin)
    kc = jnp.zeros((2, 12, 2, 8))
    vc = jnp.zeros_like(kc)
    for t in range(12):
        cq, sq = R.rope_cos_sin(pos[:, t:t + 1], 8, 10000.0)
        o, kc, vc = A.decode_attend(p, x[:, t:t + 1], kc, vc,
                                    jnp.full((2,), t), cq, sq)
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, t]), atol=1e-5)


def test_moe_dropless_matches_dense_oracle():
    cfg = ModelConfig(d_model=32, n_experts=4, n_experts_per_tok=2,
                      moe_d_ff=16, n_shared_experts=1)
    p = M.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    y, aux = M.moe_ffn(p, x, cfg,
                       capacity_factor=cfg.n_experts / cfg.n_experts_per_tok,
                       group_size=8)
    y_ref = M.moe_ffn_dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert float(aux) > 0


def test_moe_aux_loss_minimal_for_uniform_router():
    """A perfectly uniform router gives aux ~= 1 (Switch normalisation)."""
    cfg = ModelConfig(d_model=8, n_experts=4, n_experts_per_tok=1,
                      moe_d_ff=8)
    logits = jnp.zeros((64, 4))
    _, _, aux = M.route_topk(logits, 1, 64)
    np.testing.assert_allclose(float(aux), 1.0, atol=0.3)


def test_moe_capacity_drops_tokens_when_skewed():
    cfg = ModelConfig(d_model=8, n_experts=4, n_experts_per_tok=1,
                      moe_d_ff=8)
    logits = jnp.zeros((32, 4)).at[:, 0].set(10.0)    # everyone wants e0
    dispatch, combine, _ = M.route_topk(logits, 1, capacity=4)
    kept = float(jnp.sum(dispatch))
    assert kept == 4.0, "capacity must bound expert load"

import jax
import pytest

# NOTE: deliberately NO xla_force_host_platform_device_count here — tests
# must see the single real CPU device (the 512-device override is reserved
# for the dry-run launcher, per the assignment).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

"""Launcher integration: the multi-pod dry-run lowers+compiles real pairs
in a subprocess (the 512-device XLA flag must not leak into this test
process), and the CLI entry points run."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ENV = {**os.environ, "PYTHONPATH": SRC}


def _run(args, timeout=600):
    return subprocess.run([sys.executable, *args], env=ENV, timeout=timeout,
                          capture_output=True, text=True)


@pytest.mark.slow
def test_dryrun_tconst_long_context(tmp_path):
    """The paper-technique pair: smollm long_500k lowers serve_step with an
    O(1) cache on the 16x16 production mesh."""
    out = tmp_path / "dr.json"
    r = _run(["-m", "repro.launch.dryrun", "--arch", "smollm-360m",
              "--shape", "long_500k", "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text())[0]
    assert rec["attention_mode"] == "tconst"
    assert rec["memory"]["peak_bytes_est"] < 16 * 2**30
    assert rec["cost"]["flops"] > 0


@pytest.mark.slow
def test_dryrun_multi_pod_mesh(tmp_path):
    out = tmp_path / "dr.json"
    r = _run(["-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
              "--shape", "decode_32k", "--multi-pod", "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text())[0]
    assert rec["mesh"] == "2x16x16"


@pytest.mark.slow
def test_train_cli_runs():
    r = _run(["-m", "repro.launch.train", "--arch", "tconst-41m",
              "--reduced", "--steps", "3", "--batch", "2", "--seq", "16",
              "--log-every", "1"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "loss=" in r.stdout


@pytest.mark.slow
def test_serve_cli_runs():
    r = _run(["-m", "repro.launch.serve", "--arch", "tconst-41m",
              "--reduced", "--prompt-len", "12", "--gen", "10",
              "--batch", "1"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "cache-hit steps" in r.stdout


def test_mesh_factory_shapes():
    from repro.launch.mesh import make_production_mesh
    # on 1 device we can only validate the requested logical shape fails
    # gracefully; the factory itself is exercised by the dry-run subprocess
    with pytest.raises(Exception):
        make_production_mesh()        # 256 devices not available here

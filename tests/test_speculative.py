"""Speculative decoding (PR 10): verify-exact acceptance as a fixed-
shape batch op.

The contract under test, at every layer:

* **spec_chunk == decode_chunk** — one speculative round's emitted
  tokens, per-slot key-chain positions, ``done`` flags and counters all
  match what ``n_steps=m`` sequential steps would have produced.
  Acceptance is a counter advance; rollback is NOT advancing — there is
  no KV rewrite, so the resident state after a round with ``m`` accepted
  tokens must be step-for-step indistinguishable from the sequential
  state.
* **verify_chunk == stepping** — the verify logits at position ``c``
  equal the logits sequential decode produces after feeding
  ``feed[:, :c+1]`` (the dense oracle; the CI pallas-interpret lane
  re-runs this suite with the kernels swapped in).
* **drafts are throughput, never correctness** — an adversarial drafter
  (or any drafter) cannot change a stream, only its wall-clock; the
  scheduler matrix asserts token-identity against the non-speculative
  run across families x layouts, greedy and sampled.
* **paged invariants survive speculation** — rejected draft positions
  never leak into shared pages: CoW/refcount accounting closes out
  exactly as without speculation.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.models.api import (build_decode, decode_chunk, spec_chunk,
                              speculative_acceptance)
from repro.serving.engine import Engine
from repro.serving.metrics import ServingTelemetry
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session
from repro.serving.speculative import (Drafter, NGramDrafter,
                                       TConstModelDrafter, get_drafter)

import parity

K = 4


class AdversarialDrafter(Drafter):
    """Worst-case drafter: proposes a constant stream of the same token,
    maximally wrong on purpose — verify-exactness must reduce it to a
    slower sequential decode, never a different one."""

    name = "adversarial"

    def __init__(self, slots: int, token: int):
        self.slots = slots
        self.token = int(token)

    def admit(self, slot: int, tokens) -> None:
        pass                                     # stateless on purpose

    def observe(self, slot: int, tokens) -> None:
        pass

    def release(self, slot: int) -> None:
        pass

    def propose_batch(self, k: int) -> np.ndarray:
        return np.full((self.slots, k), self.token, np.int32)


def _per_slot_keys(b, seed=0):
    return jnp.stack([jax.random.PRNGKey(seed + i) for i in range(b)])


def _prefilled(family_name, kind=None, b=2, max_len=96, prompt_len=13):
    """(decode, params, state, token, cfg): a prefiled B-slot decode
    ready for chunk-level comparisons."""
    cfg, api, params = parity.family(family_name)
    decode = build_decode(cfg, parity.layout_spec(kind) if kind else None)
    rng = np.random.RandomState(7)
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab_size, size=(b, prompt_len)), jnp.int32)}
    extras = parity.extras_for(cfg)
    if extras is not None:
        batch["audio_feats"] = jnp.broadcast_to(
            jnp.asarray(extras["audio_feats"])[None],
            (b,) + extras["audio_feats"].shape)
    logits, state = jax.jit(
        lambda p, bt: decode.prefill(p, bt, max_len))(params, batch)
    token = jnp.argmax(logits, -1).astype(jnp.int32)
    return decode, params, state, token, cfg


def _run_chunk(decode, params, state, token, key, n):
    b = token.shape[0]
    return decode_chunk(decode, params, state, token, key,
                        jnp.zeros((b,)), jnp.ones((b,), bool), n)


def _assert_same_continuation(decode, params, sa, ta, ka, sb, tb, kb,
                              n=3, label=""):
    """Two (state, token, key) triples must be observationally identical:
    the next n sequential tokens and key chains agree bitwise."""
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb),
                                  err_msg=f"{label}: last token differs")
    np.testing.assert_array_equal(np.asarray(ka), np.asarray(kb),
                                  err_msg=f"{label}: key chain diverged")
    xa, _, _ = _run_chunk(decode, params, sa, ta, ka, n)
    xb, _, _ = _run_chunk(decode, params, sb, tb, kb, n)
    np.testing.assert_array_equal(
        np.asarray(xa), np.asarray(xb),
        err_msg=f"{label}: continuation diverged — the committed state "
                f"is not the sequential state")


# ---------------------------------------------------------------------------
# spec_chunk == decode_chunk: the rollback-free state machine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged", "paged_int8"])
def test_spec_chunk_full_accept_equals_k_plus_1_steps(kind):
    """A perfect draft (the model's own continuation) commits k+1 tokens
    in ONE dispatch, and the state is the k+1-step sequential state."""
    decode, params, state, token, _ = _prefilled("lm", kind)
    key = _per_slot_keys(token.shape[0])
    b = token.shape[0]
    draft, s_seq, k_seq = _run_chunk(decode, params, state, token, key, K)
    seq_toks, s_seq1, k_seq1 = _run_chunk(decode, params, state, token,
                                          key, K + 1)

    toks, m, last, s_spec, k_spec = spec_chunk(
        decode, params, state, token, draft, key,
        jnp.zeros((b,)), jnp.ones((b,), bool))
    assert (np.asarray(m) == K + 1).all(), \
        f"perfect draft not fully accepted: m={np.asarray(m)}"
    np.testing.assert_array_equal(np.asarray(toks)[:, :K + 1],
                                  np.asarray(seq_toks))
    _assert_same_continuation(decode, params, s_spec, last, k_spec,
                              s_seq1, seq_toks[:, -1], k_seq1,
                              label=f"full-accept/{kind}")


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_spec_chunk_full_reject_equals_one_step(kind):
    """An all-wrong draft still commits the bonus token (m=1) and the
    state equals ONE sequential step — rejected positions were written
    to the resident KV but the counters never advanced over them."""
    decode, params, state, token, cfg = _prefilled("lm", kind)
    key = _per_slot_keys(token.shape[0])
    b = token.shape[0]
    real, _, _ = _run_chunk(decode, params, state, token, key, K)
    draft = (real + 1) % cfg.vocab_size          # != real everywhere
    seq_toks, s_seq, k_seq = _run_chunk(decode, params, state, token,
                                        key, 1)
    toks, m, last, s_spec, k_spec = spec_chunk(
        decode, params, state, token, draft, key,
        jnp.zeros((b,)), jnp.ones((b,), bool))
    assert (np.asarray(m) == 1).all()
    np.testing.assert_array_equal(np.asarray(toks)[:, :1],
                                  np.asarray(seq_toks))
    _assert_same_continuation(decode, params, s_spec, last, k_spec,
                              s_seq, seq_toks[:, -1], k_seq,
                              label=f"full-reject/{kind}")


def test_spec_chunk_respects_tconst_window_budget():
    """tconst caps acceptance at the W_og boundary: samples past the
    window resync are garbage, so m <= max(w_og - gen_len, 1) — even a
    perfect draft cannot commit across the boundary, and the committed
    prefix still equals the sequential stream."""
    decode, params, state, token, _ = _prefilled("tconst", b=1)
    key = _per_slot_keys(1)
    draft, _, _ = _run_chunk(decode, params, state, token, key, K)
    budget = int(np.asarray(
        decode.verify_budget(decode.maybe_sync(params, state)))[0])
    toks, m, last, s_spec, k_spec = spec_chunk(
        decode, params, state, token, draft, key,
        jnp.zeros((1,)), jnp.ones((1,), bool))
    mm = int(np.asarray(m)[0])
    assert 1 <= mm <= max(budget, 1), \
        f"m={mm} escaped the window budget {budget}"
    seq_toks, s_seq, k_seq = _run_chunk(decode, params, state, token,
                                        key, mm)
    np.testing.assert_array_equal(np.asarray(toks)[:, :mm],
                                  np.asarray(seq_toks))
    _assert_same_continuation(decode, params, s_spec, last, k_spec,
                              s_seq, seq_toks[:, -1], k_seq,
                              label="tconst-budget")


def test_spec_chunk_eos_truncates_and_sets_done():
    """An EOS sampled inside the accepted prefix truncates acceptance at
    it (inclusive) and raises the on-device done flag, exactly like the
    sequential path."""
    decode, params, state, token, _ = _prefilled("lm", b=1)
    key = _per_slot_keys(1)
    seq, _, _ = _run_chunk(decode, params, state, token, key, K)
    arr = np.asarray(seq)[0]
    # pick an EOS id at the FIRST position where it occurs (a repeated
    # greedy token would otherwise shift the truncation point earlier)
    p = next(i for i in range(1, K) if arr[i] not in arr[:i])
    eos = jnp.asarray([int(arr[p])], jnp.int32)
    draft, _, _ = _run_chunk(decode, params, state, token, key, K)
    toks, m, last, s_spec, _ = spec_chunk(
        decode, params, state, token, draft, key,
        jnp.zeros((1,)), jnp.ones((1,), bool), eos=eos)
    assert int(np.asarray(m)[0]) == p + 1        # EOS position inclusive
    assert bool(np.asarray(s_spec.bookkeeping["done"])[0])
    np.testing.assert_array_equal(np.asarray(toks)[0, :p + 1], arr[:p + 1])


def test_spec_chunk_inactive_rows_frozen():
    """Inactive rows: m == 0, echoed token, key NOT advanced, and the
    row's next-step logits bit-identical to the untouched state's."""
    decode, params, state, token, _ = _prefilled("lm", b=2)
    key = _per_slot_keys(2)
    draft, _, _ = _run_chunk(decode, params, state, token, key, K)
    active = jnp.asarray([True, False])
    toks, m, last, s_spec, k_spec = spec_chunk(
        decode, params, state, token, draft, key,
        jnp.zeros((2,)), active)
    assert int(np.asarray(m)[1]) == 0
    assert (np.asarray(toks)[1] == int(np.asarray(token)[1])).all()
    np.testing.assert_array_equal(np.asarray(k_spec)[1],
                                  np.asarray(key)[1])
    l_ref, _ = decode.step(params, state, token)
    l_got, _ = decode.step(params, s_spec, token)
    np.testing.assert_array_equal(np.asarray(l_ref)[1],
                                  np.asarray(l_got)[1],
                                  err_msg="frozen row's state changed")


def test_verify_chunk_logits_match_stepping():
    """The dense oracle: verify logits at position c == the logits
    sequential decode emits after feeding feed[:, :c+1].  (The CI
    pallas-interpret lane re-runs this with the kernel path active.)"""
    decode, params, state, token, cfg = _prefilled("lm")
    rng = np.random.RandomState(1)
    feed = jnp.concatenate([
        token[:, None],
        jnp.asarray(rng.randint(1, cfg.vocab_size, size=(2, K)),
                    jnp.int32)], axis=1)
    v_logits, _ = jax.jit(decode.verify_chunk)(params, state, feed)
    s = state
    for c in range(K + 1):
        step_logits, s = decode.step(params, s, feed[:, c])
        np.testing.assert_allclose(
            np.asarray(v_logits)[:, c], np.asarray(step_logits),
            rtol=2e-5, atol=2e-5,
            err_msg=f"verify position {c} disagrees with stepping")


def test_speculative_acceptance_rule_basics():
    """Spot checks of the pure acceptance rule (exhaustive properties
    live in tests/test_property.py)."""
    feed = jnp.asarray([[5, 7, 8, 9]])           # token + 3-draft
    live = jnp.ones((1,), bool)
    big = jnp.full((1,), 1 << 20, jnp.int32)
    # samples agree with the first 2 draft tokens -> m = 3
    m, hit = speculative_acceptance(
        feed, jnp.asarray([[7, 8, 1, 2]]), big, live)
    assert int(m[0]) == 3 and not bool(hit[0])
    # budget caps acceptance
    m, _ = speculative_acceptance(
        feed, jnp.asarray([[7, 8, 9, 4]]), jnp.asarray([2]), live)
    assert int(m[0]) == 2
    # budget 0 still commits the bonus token
    m, _ = speculative_acceptance(
        feed, jnp.asarray([[7, 8, 9, 4]]), jnp.asarray([0]), live)
    assert int(m[0]) == 1
    # EOS inside the prefix truncates inclusively and reports the hit
    m, hit = speculative_acceptance(
        feed, jnp.asarray([[7, 8, 9, 4]]), big, live,
        eos=jnp.asarray([8]))
    assert int(m[0]) == 2 and bool(hit[0])


# ---------------------------------------------------------------------------
# scheduler matrix: speculative streams == plain streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged", "paged_int8"])
@pytest.mark.parametrize("family", ["tconst", "lm"])
def test_scheduler_spec_stream_identical(family, kind):
    """The acceptance bar: --speculate k changes wall-clock only.  Every
    session's stream under speculation is token-identical to the plain
    scheduler's, across families x layouts, and the rounds really were
    speculative (spec_chunk stats, k+1 forwarded positions each)."""
    _, sched = parity.stream_parity_case(
        family, kind, variant_kw={"speculate": K}, gen=8,
        label=f"spec {family}/{kind}")
    rounds = [s for s in sched.stats if s.kind == "spec_chunk"]
    assert rounds, "speculate=k never dispatched a verify round"
    assert all(s.forward_tokens == K + 1 for s in rounds)
    assert not any(s.kind == "chunk" for s in sched.stats), \
        "speculative scheduler fell back to plain chunks"


@pytest.mark.parametrize("family,kind", [("tlin", "paged"),
                                         ("encdec", "dense")])
def test_scheduler_spec_stream_identical_other_families(family, kind):
    parity.stream_parity_case(family, kind, variant_kw={"speculate": K},
                              gen=8, label=f"spec {family}/{kind}")


def test_scheduler_spec_sampled_temperature_identical():
    """Per-slot key chains make verify-exactness hold at temperature > 0
    too: each slot's chain advances by exactly its accepted count.
    Explicit per-session seeds pin the chains across runs (unseeded
    sessions derive keys from the global session id)."""
    parity.stream_parity_case(
        "tconst", "paged", variant_kw={"speculate": K}, gen=8,
        session_kw={"temperature": 0.8, "seed": 11},
        label="spec sampled")


def test_scheduler_spec_adversarial_drafter_exact():
    """A maximally wrong drafter degrades throughput to sequential,
    never the stream."""
    cfg, _, _ = parity.family("lm")
    _, sched = parity.stream_parity_case(
        "lm", "paged",
        variant_kw={"speculate": K,
                    "drafter": AdversarialDrafter(2, cfg.vocab_size - 1)},
        gen=8, label="adversarial drafter")
    rounds = [s for s in sched.stats if s.kind == "spec_chunk"]
    # every round commits exactly the bonus token per live slot
    assert all(s.tokens <= 2 for s in rounds)


def test_scheduler_spec_cow_refcounts_close_out():
    """Prefix sharing under speculation: rejected draft positions are
    written through the slot's OWN pages (the CoW fork happened at
    admission/resync as usual), so shared-page refcounts and the free
    pool close out exactly as without speculation — and the streams
    match the non-speculative sharing run."""
    cfg, _, params = parity.family("tlin")
    prompts = parity.shared_prompts(cfg, 3)
    spec = parity.layout_spec("paged", pool_pages=20)
    common = dict(gen=8, stagger=False, slots=3, prefix_sharing=True)
    ref, _ = parity.serve_streams(cfg, params, prompts, spec, **common)
    out, sched = parity.serve_streams(cfg, params, prompts, spec,
                                      speculate=K, **common)
    parity.assert_streams_equal(ref, out, "spec + prefix sharing")
    assert (sched.page_refcounts() == 0).all(), \
        "speculation leaked page references"
    assert len(sched.free_pages) == 20
    assert not sched._prefix_map and not sched._page_key


def test_scheduler_spec_telemetry_reports_acceptance():
    cfg, _, params = parity.family("lm")
    prompts = parity.make_prompts(cfg, (21, 34, 17))
    tel = ServingTelemetry()
    parity.serve_streams(cfg, params, prompts, None, gen=8,
                         speculate=K, telemetry=tel)
    spec = tel.summary()["spec_decode"]
    assert spec is not None and spec["sessions"] == 3
    assert spec["rounds"] > 0
    assert spec["drafted"] == spec["rounds"] * K
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert spec["tokens_per_round"] >= 1.0


def test_scheduler_rejects_speculation_where_unsupported():
    cfg = reduced(get_config("mamba2_130m"), dtype="float32")
    decode = build_decode(cfg)
    assert not decode.supports_speculative()
    with pytest.raises(ValueError, match="speculat"):
        SlotScheduler(decode, None, slots=2, max_len=64, chunk_size=4,
                      speculate=K)


# ---------------------------------------------------------------------------
# Engine path (shared batch key -> greedy only)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,kind", [("tconst", "dense"),
                                         ("lm", "paged")])
def test_engine_speculative_greedy_identical(family, kind):
    cfg, api, params = parity.family(family)
    rng = np.random.RandomState(5)
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab_size, size=(2, 12)), jnp.int32)}
    spec = parity.layout_spec(kind) if kind != "dense" else None
    ref = Engine(api, params, max_len=64,
                 layout=spec).generate(dict(batch), 10)
    eng = Engine(api, params, max_len=64, layout=spec)
    out = eng.generate_speculative(dict(batch), 10, k=K)
    np.testing.assert_array_equal(ref, out)
    assert eng.spec_rounds <= 10


def test_engine_speculative_model_drafter_identical():
    cfg, api, params = parity.family("tconst")
    batch = {"tokens": jnp.arange(1, 13, dtype=jnp.int32)[None] + 3}
    ref = Engine(api, params, max_len=64).generate(dict(batch), 8)
    eng = Engine(api, params, max_len=64)
    drafter = get_drafter("tconst", slots=1, vocab=cfg.vocab_size,
                          max_len=64)
    out = eng.generate_speculative(dict(batch), 8, k=3, drafter=drafter)
    np.testing.assert_array_equal(ref, out)


def test_engine_speculative_rejects_sampling():
    """One shared batch key cannot reproduce per-position sampled draws
    — the Engine refuses instead of silently changing streams."""
    cfg, api, params = parity.family("lm")
    eng = Engine(api, params, max_len=64, sample_temperature=0.8)
    with pytest.raises(ValueError, match="greedy"):
        eng.generate_speculative(
            {"tokens": jnp.ones((1, 8), jnp.int32)}, 4)


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------


def test_ngram_drafter_continues_repeated_motif():
    d = NGramDrafter(2)
    d.admit(0, [5, 6, 7, 5, 6])
    d.admit(1, [9])
    prop = d.propose_batch(3)
    assert prop.shape == (2, 3) and prop.dtype == np.int32
    # trailing (5, 6) last occurred at the start, followed by 7
    assert prop[0, 0] == 7
    assert (prop[1] == 9).all()                  # repeat-last fallback

    d.release(0)
    assert (d.propose_batch(3)[0] == 0).all()    # released slot: zeros


def test_ngram_drafter_window_bounded():
    d = NGramDrafter(1, window=16)
    d.admit(0, list(range(100)))
    assert len(d._hist[0]) == 16
    d.observe(0, list(range(40)))
    assert len(d._hist[0]) == 16


def test_tconst_model_drafter_shapes_and_overflow():
    d = TConstModelDrafter(2, vocab=512, max_len=32)
    d.admit(0, [1, 2, 3, 4])
    prop = d.propose_batch(3)
    assert prop.shape == (2, 3) and prop.dtype == np.int32
    assert (prop[1] == 0).all()                  # empty slot proposes 0
    assert (0 <= prop).all() and (prop < 512).all()
    # overflowing the drafter's own max_len must disable the slot, not
    # crash the serving loop
    d.observe(0, list(range(1, 40)))
    prop = d.propose_batch(3)
    assert prop.shape == (2, 3)

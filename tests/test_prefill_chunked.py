"""Chunked, KV-conditioned prefill + bucketed compile shapes (PR 5).

Four concerns:

1. **Stream parity** — chunked admission must be token-identical to the
   one-shot ``prefill_into_slot`` admission for every cache layout x
   model family in the matrix (int8 layouts quantize the SAME values on
   write, so even they stay exact here).
2. **Tail-only compute** — with prefix sharing, a session whose prompt
   shares a resident page-aligned prefix must FORWARD only its unshared
   tail (padded to the chunk grid): asserted on the scheduler's
   ``admit_stats.forward_tokens``.  The tconst family is exempt by
   design (the paper's resync rebuilds the compressed ctx KV from the
   full history) — its chunked admission is the BUCKETED fixed-shape
   prefill.
3. **Bucketing** — K distinct prompt lengths must produce at most
   bucket-count (chunk-shape x variant) compile-tagged admissions,
   instead of one per length.
4. **Layout primitives** — ``DecodeState.read_slot`` (seeding the row
   cache from resident pages) and ``write_span`` (chunk-granular page
   writes, adopted pages redirected to TRASH via ``min_page``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import parity
from repro.config import get_config, reduced
from repro.models import layouts as LT
from repro.models.api import build_decode, build_model
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session

PAGE = parity.PAGE
CHUNK = 16

# family fixtures, layout specs, extras, prompts and the scheduler
# driver live in tests/parity.py — shared with the tiering, sharding and
# prefix-sharing suites
_spec = parity.layout_spec
_extras = parity.extras_for
_shared_prompts = parity.shared_prompts


@pytest.fixture(scope="module")
def lm_setup():
    return parity.family("lm")


@pytest.fixture(scope="module")
def tlin_setup():
    return parity.family("tlin")


@pytest.fixture(scope="module")
def tconst_setup():
    return parity.family("tconst")


@pytest.fixture(scope="module")
def encdec_setup():
    return parity.family("encdec")


def _serve(cfg, params, prompts, spec, prefill_chunk, **kw):
    return parity.serve_streams(cfg, params, prompts, spec,
                                prefill_chunk=prefill_chunk, **kw)


# ---------------------------------------------------------------------------
# 1. stream parity: chunked == one-shot admission, layouts x families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged", "paged_int8"])
@pytest.mark.parametrize("family", ["tconst", "tlin", "lm", "encdec"])
def test_chunked_admission_token_identical(family, kind):
    """Chunked admission streams match one-shot admission exactly for
    every layout x family, under staggered continuous batching."""
    _, sched = parity.stream_parity_case(
        family, kind, variant_kw={"prefill_chunk": CHUNK},
        label=f"chunked admission {family}/{kind}")
    assert all(s.forward_tokens is not None for s in sched.admit_stats)


# ---------------------------------------------------------------------------
# 2. tail-only compute for shared prefixes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["paged", "paged_int8"])
def test_shared_prefix_admission_forwards_only_the_tail(lm_setup, kind):
    """A prompt whose page-aligned prefix is resident (adopted from the
    prefix map) runs forward compute over <= tail + one chunk of tokens;
    the cold admission pays the whole prompt.  Streams stay identical to
    the unchunked sharing run AND to the solo run."""
    cfg, api, params = lm_setup
    prompts = _shared_prompts(cfg, 3)          # 48 shared + 8 tail
    spec = _spec(kind)
    # 3 slots: all sessions admit while the prefix is resident (with 2,
    # the third would only admit after both sharers retired — refcount 0
    # recycles the pages and the admission goes cold)
    ref, _ = _serve(cfg, params, prompts, spec, None, stagger=False,
                    slots=3, prefix_sharing=True)
    out, sched = _serve(cfg, params, prompts, spec, CHUNK, stagger=False,
                        slots=3, prefix_sharing=True)
    assert out == ref
    fwd = [s.forward_tokens for s in sched.admit_stats]
    tail = len(prompts[0]) - 48
    # first admission is cold: full prompt padded to the chunk grid
    assert fwd[0] >= len(prompts[0])
    # later admissions adopt the 3 resident prefix pages: forward compute
    # covers at most the tail plus one chunk of padding
    assert all(f <= tail + CHUNK for f in fwd[1:]), fwd
    assert all(f < fwd[0] for f in fwd[1:]), fwd
    # solo reference through the same layout
    solo, _ = _serve(cfg, params, prompts[:1], spec, CHUNK, stagger=False)
    assert out[0] == solo[0]


def test_fully_resident_prompt_still_yields_admission_logits(lm_setup):
    """When the adopted prefix covers the WHOLE page-aligned prompt, the
    driver still forwards the final chunk (for the first sampled token)
    but redirects its page writes to TRASH — the adopted pages are never
    written and the stream stays exact."""
    cfg, api, params = lm_setup
    rng = np.random.RandomState(4)
    p = rng.randint(1, cfg.vocab_size, size=3 * PAGE).astype(np.int32)
    spec = _spec("paged")
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                          max_len=128, chunk_size=4, prefix_sharing=True,
                          prefill_chunk=CHUNK)
    s1 = sched.submit(Session(p.copy(), max_new_tokens=6))
    s2 = sched.submit(Session(p.copy(), max_new_tokens=6))
    sched.admit_pending()
    refs = sched.page_refcounts()
    assert int((refs > 1).sum()) == 3          # all 3 prompt pages shared
    shared_pages = np.nonzero(refs > 1)[0]

    def snapshot():
        return {f: np.take(np.asarray(a), shared_pages,
                           axis=sched.layout._length_axis(f) - 1).copy()
                for f, a in sched.state.kv.items()
                if sched.layout._length_axis(f) is not None}

    before = snapshot()
    sched.run()
    solo, _ = _serve(cfg, params, [p], spec, CHUNK, stagger=False)
    assert s1.tokens == solo[0] and s2.tokens == solo[0]
    # the recomputed chunk never wrote the shared pages
    after = snapshot()
    for f in before:
        np.testing.assert_array_equal(
            after[f], before[f],
            err_msg=f"fully-resident admission wrote shared {f}")


# ---------------------------------------------------------------------------
# 3. bucketing: K distinct prompt lengths, <= bucket-count compiles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["tconst", "lm", "encdec"])
def test_bucketing_bounds_compiled_admissions(family, request):
    """With chunked admission the compile signature is the bucket (chunk
    shape x variants), not the prompt length: K distinct lengths tag at
    most ONE cold-admission compile, where the one-shot path tags K."""
    cfg, api, params = request.getfixturevalue(f"{family}_setup")
    lengths = (17, 26, 35, 44)
    rng = np.random.RandomState(5)
    prompts = [rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in lengths]

    def tagged(prefill_chunk):
        sched = SlotScheduler(build_decode(cfg), params, slots=1,
                              max_len=128, chunk_size=4,
                              prefill_chunk=prefill_chunk)
        for p in prompts:
            sched.submit(Session(p, max_new_tokens=1,
                                 extras=_extras(cfg)))
            sched.admit_pending()
        assert len(sched.admit_stats) == len(lengths)
        return sum(1 for s in sched.admit_stats if s.compiled)

    assert tagged(CHUNK) == 1          # one bucket: cold chunked variant
    assert tagged(None) == len(lengths)   # one-shot: one per length


def test_prefill_chunk_must_align_to_page_grid(lm_setup):
    cfg, api, params = lm_setup
    with pytest.raises(ValueError, match="multiple of the page size"):
        SlotScheduler(build_decode(cfg, _spec("paged")), params, slots=1,
                      max_len=128, prefill_chunk=PAGE + 1)
    with pytest.raises(ValueError, match="must be positive"):
        SlotScheduler(build_decode(cfg), params, slots=1, max_len=128,
                      prefill_chunk=0)


def test_build_decode_carries_prefill_chunk_default(lm_setup):
    """The knob rides the decode protocol: build_decode(prefill_chunk=N)
    is the scheduler's default chunk size."""
    cfg, api, params = lm_setup
    dec = build_decode(cfg, None, prefill_chunk=CHUNK)
    sched = SlotScheduler(dec, params, slots=1, max_len=128)
    assert sched.prefill_chunk == CHUNK
    sched.submit(Session(np.arange(1, 20, dtype=np.int32),
                         max_new_tokens=1))
    sched.admit_pending()
    assert sched.admit_stats[0].forward_tokens == 2 * CHUNK  # 19 -> 32


# ---------------------------------------------------------------------------
# 4. layout primitives: read_slot / write_span
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged", "int8", "paged_int8"])
def test_read_slot_matches_merged_oracle(lm_setup, kind):
    """read_slot must equal the merged() oracle's row for every layout
    (int8: both sides dequantize the same stored values)."""
    cfg, api, params = lm_setup
    dec = build_decode(cfg, _spec(kind) if kind != "int8"
                       else LT.LayoutSpec(kind="int8"))
    sched = SlotScheduler(dec, params, slots=2, max_len=64, chunk_size=4)
    sched.submit(Session(np.arange(1, 22, dtype=np.int32),
                         max_new_tokens=2))
    sched.step()
    parity.assert_read_slot_matches_merged(sched.state)


@pytest.mark.parametrize("kind", ["paged", "paged_int8"])
def test_write_span_chunk_granular_page_writes(lm_setup, kind):
    """write_span writes exactly the pages covering [start, start+C) of
    the slot's table — other slots' pages and entries below min_page
    (adopted) are untouched."""
    cfg, api, params = lm_setup
    dec = build_decode(cfg, _spec(kind))
    state = dec.init_state(2, 64)                    # 4 pages per slot
    pt = jnp.asarray([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    state = state.with_bookkeeping(**{LT.PAGE_TABLE: pt})
    rng = np.random.RandomState(0)
    C = 2 * PAGE                                     # span = 2 whole pages
    chunk = {}
    for f in ("k", "v"):
        sh = state.dense_shapes()[f].shape           # (layers,2,64,KV,hd)
        chunk[f] = 0.1 * jnp.asarray(rng.randn(
            sh[0], 1, C, sh[3], sh[4]).astype(np.float32))
    before = {f: np.asarray(v).copy() for f, v in state.kv.items()}
    out = jax.jit(lambda st, s: st.write_span(
        s, chunk, {"k": 2, "v": 2}, jnp.int32(0),
        min_page=jnp.int32(1)))(state, np.int32(0))
    merged = out.merged()
    for f in ("k", "v"):
        got = np.asarray(merged[f][:, 0])            # slot 0 row
        want = np.asarray(chunk[f][:, 0])
        tol = 0.0
        if kind == "paged_int8":
            q, s = LT.quantize_int8(chunk[f])
            want = np.asarray(LT.dequantize_int8(q, s, jnp.float32)[:, 0])
            tol = 1e-6          # jit-fused quantize: scale within 1 ULP
        # page 1 of the span is written...
        np.testing.assert_allclose(got[:, PAGE:C], want[:, PAGE:C],
                                   rtol=0, atol=tol)
        # ...page 0 (below min_page = "adopted") is redirected to TRASH
        np.testing.assert_array_equal(got[:, :PAGE],
                                      np.zeros_like(got[:, :PAGE]))
    # the OTHER slot's pool pages are bit-identical
    for pf, arr in out.kv.items():
        la = out.layout._length_axis(pf)
        if la is None:
            continue
        np.testing.assert_array_equal(
            np.take(np.asarray(arr), range(4, 8), axis=la - 1),
            np.take(before[pf], range(4, 8), axis=la - 1),
            err_msg=f"write_span leaked into slot 1 pages of {pf}")


def test_write_span_dense_and_int8_positional(lm_setup):
    """Non-paged layouts write the span positionally at (slot, start)."""
    cfg, api, params = lm_setup
    for kind in ("dense", "int8"):
        dec = build_decode(cfg, LT.LayoutSpec(kind=kind))
        state = dec.init_state(2, 64)
        rng = np.random.RandomState(1)
        sh = state.dense_shapes()["k"].shape
        chunk = {"k": jnp.asarray(rng.randn(
            sh[0], 1, CHUNK, *sh[3:]).astype(np.float32)) * 0.1}
        out = state.write_span(np.int32(1), chunk, {"k": 2},
                               jnp.int32(8))
        got = np.asarray(out.merged()["k"][:, 1])
        want = np.asarray(chunk["k"][:, 0])
        tol = 0.0 if kind == "dense" else 2e-3      # int8 quantize-on-write
        np.testing.assert_allclose(got[:, 8:8 + CHUNK], want[:, :CHUNK],
                                   rtol=0, atol=tol)
        # slot 0 untouched
        np.testing.assert_array_equal(
            np.asarray(out.merged()["k"][:, 0]),
            np.asarray(state.merged()["k"][:, 0]))


# ---------------------------------------------------------------------------
# recurrent-state families: padding must not advance the ssm/conv state
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mamba2_130m", "hymba_1_5b"])
def test_chunked_admission_recurrent_state_families(arch):
    """The last chunk's zero padding must not advance the ssm/conv
    recurrent state (dt is masked, the conv window ends at the true
    length) — streams match the one-shot admission exactly."""
    cfg = reduced(get_config(arch), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (19, 33)]
    ref, _ = _serve(cfg, params, prompts, None, None, gen=5)
    out, _ = _serve(cfg, params, prompts, None, CHUNK, gen=5)
    assert out == ref


def test_chunk_grid_overflow_falls_back_to_one_shot(lm_setup):
    """A prompt whose chunk-grid padding would spill past max_len (where
    dynamic_update_slice would CLAMP onto real positions) must fall back
    to one-shot admission transparently."""
    cfg, api, params = lm_setup
    rng = np.random.RandomState(6)
    p = rng.randint(1, cfg.vocab_size, size=65).astype(np.int32)

    def serve(pc):
        sched = SlotScheduler(build_decode(cfg), params, slots=1,
                              max_len=74, chunk_size=4, prefill_chunk=pc)
        s = sched.submit(Session(p, max_new_tokens=5))
        sched.run()
        return s.tokens, sched

    out, sched = serve(CHUNK)          # grid 5*16 = 80 > 74: fallback
    assert sched.admit_stats[0].forward_tokens == 65   # one-shot, unpadded
    ref, _ = serve(None)
    assert out == ref


def test_hybrid_sharing_forwards_full_prompt_for_recurrent_state():
    """The ssm/conv recurrent state is a function of the FULL prompt and
    cannot be reconstructed from adopted KV pages — a recurrent-state
    family's sharing admission must forward from position 0 (adopted
    pages still save the writes), and its stream must stay exact."""
    cfg = reduced(get_config("hymba_1_5b"), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = _shared_prompts(cfg, 2, common_len=32, seed=7)   # 40 tokens
    spec = _spec("paged")
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                          max_len=128, chunk_size=4, prefix_sharing=True,
                          prefill_chunk=CHUNK)
    ss = [sched.submit(Session(p, max_new_tokens=6)) for p in prompts]
    sched.admit_pending()
    assert (sched.page_refcounts() > 1).sum() == 2    # pages ARE adopted
    fwd = [s.forward_tokens for s in sched.admit_stats]
    assert fwd[1] >= len(prompts[1])   # full forward, not tail-only
    sched.run()
    for s, p in zip(ss, prompts):
        solo, _ = _serve(cfg, params, [p], spec, CHUNK, stagger=False)
        assert s.tokens == solo[0], "sharing corrupted the ssm state"


def test_vlm_admission_falls_back_to_one_shot():
    """Vision sessions keep the one-shot path (prompt-length-shaped
    vision mask): the scheduler must route them transparently."""
    cfg = reduced(get_config("qwen2_vl_2b"), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    Tv = cfg.frontend_tokens
    mask = np.zeros((24,), bool)
    mask[:Tv] = True
    extras = {"vision_embeds": np.zeros((Tv, cfg.frontend_dim),
                                        np.float32),
              "vision_mask": mask}
    sched = SlotScheduler(build_decode(cfg), params, slots=1, max_len=80,
                          chunk_size=4, prefill_chunk=CHUNK)
    s = sched.submit(Session(np.arange(1, 25, dtype=np.int32),
                             max_new_tokens=5, extras=extras))
    sched.run()
    assert s.done and len(s.tokens) == 5
    # one-shot fallback forwards the whole prompt, unpadded
    assert sched.admit_stats[0].forward_tokens == 24

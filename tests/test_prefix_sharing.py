"""Paged-pool admission regressions + prefix-sharing / copy-on-write.

Three concerns:

1. **Admission bugs** — pool-capacity validation at ``submit`` (a
   session needing more pages than the POOL holds used to pass the
   max_len-only check and deadlock ``run()``), ``run()`` raising instead
   of busy-spinning when nothing can make progress, and bounded
   skip-ahead past a page-blocked queue head (head-of-line blocking).
2. **Prefix sharing (CoW)** — admission maps resident content-addressed
   pages instead of re-writing them; refcounted release; shared pages
   (refcount > 1) are NEVER written (the resync forks first); sessions
   sharing a prefix stay token-identical to their solo runs across
   ``{paged, paged_int8}``.
3. **Stress** — undersized pool, mixed session sizes, staggered
   submission: the scheduler must terminate with every budget honoured
   and the pool fully recycled (the deadlock class cannot regress).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.api import build_decode
from repro.serving.engine import Engine
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session

import parity


@pytest.fixture(scope="module")
def tlin_setup():
    return parity.family("tlin")


@pytest.fixture(scope="module")
def lm_setup():
    return parity.family("lm_mqa")


def _shared_prompts(cfg, n, common_len=32, tail_len=8, seed=0):
    # 32-token common prefix = exactly 2 pages at this suite's page size
    return parity.shared_prompts(cfg, n, common_len=common_len,
                                 tail_len=tail_len, seed=seed)


def _spec(kind, pool_pages):
    return parity.layout_spec(kind, pool_pages=pool_pages)


def _paged_snapshot(state, pages):
    """Content of the given pool pages for every paged field."""
    lay = state.layout
    out = {}
    for f, arr in state.kv.items():
        la = lay._length_axis(f)
        if la is None:
            continue
        out[f] = np.take(np.asarray(arr), pages, axis=la - 1).copy()
    return out


# ---------------------------------------------------------------------------
# Admission bugs: pool-capacity deadlock + head-of-line blocking
# ---------------------------------------------------------------------------


def test_submit_rejects_session_exceeding_pool_capacity(tlin_setup):
    """A session whose page need exceeds the TOTAL pool passes a
    max_len-only check but can never be admitted — submit must reject it
    up front instead of letting run() spin on it forever."""
    cfg, api, params = tlin_setup
    dec = build_decode(cfg, _spec("paged", pool_pages=4))
    sched = SlotScheduler(dec, params, slots=1, max_len=128, chunk_size=4)
    with pytest.raises(ValueError, match="could never be admitted"):
        # prompt 40 + gen 30 + chunk 4 = 74 tokens -> 5 pages > pool 4
        sched.submit(Session(np.ones(40, np.int32), max_new_tokens=30))
    assert not sched.pending


def test_run_raises_instead_of_spinning_when_stuck(tlin_setup):
    """If nothing is active and the pending head cannot be admitted, no
    future chunk can free resources — run() must raise, not busy-spin."""
    cfg, api, params = tlin_setup
    dec = build_decode(cfg, _spec("paged", pool_pages=10))
    sched = SlotScheduler(dec, params, slots=1, max_len=128, chunk_size=4)
    sched.submit(Session(np.ones(20, np.int32), max_new_tokens=8))
    sched.free_pages.clear()          # simulate leaked page accounting
    with pytest.raises(RuntimeError, match="scheduler stuck"):
        sched.run()


def test_head_of_line_blocking_bounded_skip_ahead(lm_setup):
    """One large session running, another large blocked at the head of
    the queue on pages: small sessions behind it that fit the free pool
    and a free slot must be admitted past it (the pre-fix scheduler
    stopped at the blocked head), while the head still completes."""
    cfg, api, params = lm_setup
    spec = _spec("paged", pool_pages=6)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4)
    big_a = sched.submit(Session(np.ones(40, np.int32), max_new_tokens=8))
    sched.step()                                  # A admitted: 4/6 pages
    big_b = sched.submit(Session(np.full(40, 2, np.int32),
                                 max_new_tokens=8))
    small_c = sched.submit(Session(np.full(8, 3, np.int32),
                                   max_new_tokens=4))
    small_d = sched.submit(Session(np.full(8, 4, np.int32),
                                   max_new_tokens=4))
    sched.admit_pending()
    # B (needs 4 pages, 2 free) waits; C and D leapfrog into free slots
    assert big_b.slot is None
    assert small_c.slot is not None and small_d.slot is not None
    assert sched.n_active == 3
    sched.run()
    for s in (big_a, big_b, small_c, small_d):
        assert s.done and len(s.tokens) == s.max_new_tokens
    assert len(sched.free_pages) == 6

    # skip budget 0 degenerates to strict FIFO: nothing overtakes the head
    fifo = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                         max_len=128, chunk_size=4, max_head_skips=0)
    fifo.submit(Session(np.ones(40, np.int32), max_new_tokens=8))
    fifo.step()
    fifo.submit(Session(np.full(40, 2, np.int32), max_new_tokens=8))
    small = fifo.submit(Session(np.full(8, 3, np.int32), max_new_tokens=4))
    fifo.admit_pending()
    assert small.slot is None         # budget spent: head may not be passed
    fifo.run()
    assert small.done


# ---------------------------------------------------------------------------
# Prefix sharing: CoW parity, refcounts, resync write-safety
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["paged", "paged_int8"])
def test_prefix_sharing_cow_parity_token_identical(tlin_setup, kind):
    """Sessions admitted with a shared page-aligned prompt prefix map
    the resident pages (counted once), stay token-identical to their
    solo runs through the copy-on-write resync fork, and recycle every
    page (refcount 0, map empty) after eviction."""
    cfg, api, params = tlin_setup
    spec = _spec(kind, pool_pages=14)
    prompts = _shared_prompts(cfg, 3)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    sessions = [sched.submit(Session(p, max_new_tokens=8)) for p in prompts]
    sched.admit_pending()
    refs = sched.page_refcounts()
    # stable prefix = 32 tokens (w_og=8 window part excluded) = 2 pages,
    # mapped by all three sessions; 2 private tail pages each
    assert int((refs == 3).sum()) == 2
    assert int((refs > 0).sum()) == 2 + 3 * 2
    shared_bytes = sched.assigned_kv_bytes()

    no_share = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                             max_len=128, chunk_size=4)
    for p in prompts:
        no_share.submit(Session(p, max_new_tokens=8))
    no_share.admit_pending()
    assert shared_bytes < no_share.assigned_kv_bytes()

    sched.run()
    no_share.run()
    # solo reference: one session at a time through the SAME layout
    solo = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                         max_len=128, chunk_size=4)
    for s, p in zip(sessions, prompts):
        ref = solo.submit(Session(p, max_new_tokens=8))
        solo.run()
        assert s.tokens == ref.tokens, "sharing changed the stream"
    if kind == "paged":               # exact layout: dense engine agrees
        eng = Engine(api, params, max_len=128)
        for s, p in zip(sessions, prompts):
            assert s.tokens == eng.generate(
                {"tokens": jnp.asarray(p)[None]}, 8)[0].tolist()
    assert (sched.page_refcounts() == 0).all()
    assert len(sched.free_pages) == 14           # pages recycled
    assert not sched._prefix_map and not sched._page_key


def test_resync_never_writes_shared_pages(tlin_setup):
    """The CoW invariant: a page is writable iff refcount == 1.  The
    only device-side write that can target resident prefix pages is the
    periodic resync, so at every chunk boundary, after the CoW pass,
    every slot whose resync may fire inside the coming chunk must own
    exclusively refcount-1 pages (its formerly shared pages were forked
    to fresh ones) — and pages that stay shared through the chunk come
    out bit-identical."""
    cfg, api, params = tlin_setup
    spec = _spec("paged", pool_pages=14)
    prompts = _shared_prompts(cfg, 3, seed=1)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    for p in prompts:
        sched.submit(Session(p, max_new_tokens=8))
    saw_shared = saw_fork = False
    while True:
        sched.admit_pending()
        refs_before = sched.page_refcounts()
        tables_before = [list(r) for r in sched._slot_pages]
        saw_shared = saw_shared or bool((refs_before > 1).any())
        anticipated = sched.decode.sync_anticipated(sched.state,
                                                    sched.chunk_size)
        sched._cow_before_chunk()
        refs = sched.page_refcounts()
        for slot in np.nonzero(sched.active)[0]:
            if not anticipated[slot]:
                continue
            pages = sched._slot_pages[slot]
            assert all(refs[p] == 1 for p in pages), \
                "a slot about to resync still references a shared page"
            if any(refs_before[p0] > 1 for p0 in tables_before[slot]):
                saw_fork = True      # it really forked, not just released
        # pages still shared after the CoW pass must survive the chunk
        still_shared = np.nonzero(refs > 1)[0]
        before = _paged_snapshot(sched.state, still_shared)
        if not sched.step() and not sched.pending:
            break
        after = _paged_snapshot(sched.state, still_shared)
        for f in before:
            np.testing.assert_array_equal(
                after[f], before[f],
                err_msg=f"chunk wrote shared (refcount>1) pages of {f}")
    assert saw_shared and saw_fork    # the invariant was exercised


def test_lm_prefix_sharing_persists_across_staggered_admission(lm_setup):
    """The dense-LM family has no periodic resync, so nothing ever
    rewrites resident prompt pages: sharing persists for the whole
    session lifetime, even across staggered admission — and the streams
    still match the solo runs exactly."""
    cfg, api, params = lm_setup
    spec = _spec("paged", pool_pages=10)
    pa, pb = _shared_prompts(cfg, 2, seed=2)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=2,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    sa = sched.submit(Session(pa, max_new_tokens=12))
    sched.step()                      # A decodes alone for one chunk
    sb = sched.submit(Session(pb, max_new_tokens=12))
    sched.step()
    refs = sched.page_refcounts()
    assert int((refs == 2).sum()) == 2           # 40-token prompt: the two
    # fully-covered prefix pages stay shared for the sessions' lifetime
    # (nothing rewrites them), so the pool holds 4 + 4 - 2 unique pages
    assert int((refs > 0).sum()) == 6
    # token appends land beyond the stable prefix by construction: the
    # shared pages' content survives further decode chunks bit-identical
    shared_pages = np.nonzero(refs > 1)[0]
    before = _paged_snapshot(sched.state, shared_pages)
    sched.step()
    after = _paged_snapshot(sched.state, shared_pages)
    for f in before:
        np.testing.assert_array_equal(after[f], before[f])
    sched.run()
    eng = Engine(api, params, max_len=128)
    for s, p in ((sa, pa), (sb, pb)):
        assert s.tokens == eng.generate(
            {"tokens": jnp.asarray(p)[None]}, 12)[0].tolist()
    assert (sched.page_refcounts() == 0).all()
    assert len(sched.free_pages) == 10


def test_fork_starvation_pauses_slot_instead_of_crashing(tlin_setup):
    """When the free pool cannot back a slot's copy-on-write fork, the
    slot is PAUSED for the chunk (frozen bit-identically, delivered
    nothing) rather than the scheduler raising away every in-flight
    session; it resumes — and its stream stays exact — once a retiring
    session frees pages."""
    cfg, api, params = tlin_setup
    spec = _spec("paged", pool_pages=8)
    pa, pb = _shared_prompts(cfg, 2, seed=4)          # 4 pages each, 2 shared
    small = np.arange(1, 21, dtype=np.int32) % cfg.vocab_size   # 2 pages
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    sa = sched.submit(Session(pa, max_new_tokens=8))
    sb = sched.submit(Session(pb, max_new_tokens=8))
    sc = sched.submit(Session(small, max_new_tokens=4))
    sched.step()
    # pool exhausted (4 + 2 + 2 pages): neither sharer can fork for its
    # first resync, so both sit paused with only the admission token,
    # while the independent small session decoded and retired
    assert sc.done
    assert len(sa.tokens) == 1 and len(sb.tokens) == 1
    sched.run()                       # small's pages freed -> forks happen
    eng = Engine(api, params, max_len=128)
    for s, p in ((sa, pa), (sb, pb)):
        assert s.done
        assert s.tokens == eng.generate(
            {"tokens": jnp.asarray(p)[None]}, 8)[0].tolist()
    assert (sched.page_refcounts() == 0).all()
    assert len(sched.free_pages) == 8


def test_multi_adopter_overcommit_resolves_via_pausing(tlin_setup):
    """Admission reserves fork headroom per-admission only (commitments
    are not tracked jointly), so several adopters can still overcommit
    the pool — the run must resolve through pausing + retirement, never
    wedge or crash, and every stream stays exact."""
    cfg, api, params = tlin_setup
    spec = _spec("paged", pool_pages=10)
    prompts = _shared_prompts(cfg, 3, seed=5)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    sessions = [sched.submit(Session(p, max_new_tokens=8)) for p in prompts]
    sched.run()
    eng = Engine(api, params, max_len=128)
    for s, p in zip(sessions, prompts):
        assert s.done
        assert s.tokens == eng.generate(
            {"tokens": jnp.asarray(p)[None]}, 8)[0].tolist()
    assert (sched.page_refcounts() == 0).all()
    assert len(sched.free_pages) == 10


# ---------------------------------------------------------------------------
# Stress: undersized pool, mixed sizes, staggered submission
# ---------------------------------------------------------------------------


def test_scheduler_stress_undersized_pool_mixed_sizes(tlin_setup):
    """Fast CPU stress for the deadlock class: more sessions than slots,
    mixed prompt/budget sizes on an undersized pool with prefix sharing
    on — the run must terminate with every budget honoured, the skip-
    ahead bounded, and the pool fully recycled."""
    cfg, api, params = tlin_setup
    spec = _spec("paged", pool_pages=12)
    rng = np.random.RandomState(3)
    common = rng.randint(1, cfg.vocab_size, size=32).astype(np.int32)
    sched = SlotScheduler(build_decode(cfg, spec), params, slots=3,
                          max_len=128, chunk_size=4, prefix_sharing=True)
    sessions = []
    for i in range(7):
        if i % 2 == 0:               # sharers: common prefix + 8 tail
            prompt = np.concatenate([common, rng.randint(
                1, cfg.vocab_size, size=8).astype(np.int32)])
        else:                        # small standalone prompts
            prompt = rng.randint(1, cfg.vocab_size,
                                 size=8 + 4 * (i % 3)).astype(np.int32)
        sessions.append(sched.submit(Session(prompt,
                                             max_new_tokens=4 + 2 * (i % 3))))
        if i % 3 == 2:
            sched.step()             # staggered: interleave decode chunks
    sched.run()
    for s in sessions:
        assert s.done and len(s.tokens) == s.max_new_tokens
    assert (sched.page_refcounts() == 0).all()
    assert len(sched.free_pages) == 12
    assert not sched._prefix_map
    # StepStats compile tagging: exactly the first chunk entry is marked
    chunks = [s for s in sched.stats if s.kind == "chunk"]
    assert chunks[0].compiled and not any(s.compiled for s in chunks[1:])
    admits = [s for s in sched.admit_stats]
    assert admits and admits[0].compiled

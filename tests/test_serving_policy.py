"""Scheduling-policy seam: fairness, determinism, cost-aware victims.

Four concerns:

1. **Overtake accounting regression** — the bounded head-skip budget
   must count EVERY admission of a session other than the arrival-order
   head, including RESUME-sourced re-admissions of spilled sessions
   (the pre-policy code counted queue positions, which breaks once
   resumes re-enter at the tail and a policy reorders the try list):
   a page-blocked head sees resumes overtake it at most
   ``max_head_skips`` times, then strict arrival order holds everything
   until the head admits.
2. **Stream identity** — with per-session sampling chains, a session's
   token stream at temperature > 0 is identical across scheduling
   policies and across runs, THROUGH spill/resume cycles (the bench's
   per-session identity gate, in miniature).
3. **Cost-aware victim selection** — ``spill_cost`` ranks a dense-LM
   slot by its live pages (and doubles it: cold re-admission re-pays
   the bytes) while a prompt-pure family (tconst ``admission_key``)
   re-admits for free; ``DeadlineCostPolicy`` spills the cheap slot
   and protects ITL-bound sessions.
4. **Telemetry integration** — a scheduler-attached
   ``ServingTelemetry`` records every submitted session to retirement
   with consistent counters.
"""
import jax
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.models import layouts as LT
from repro.models.api import build_decode, build_model
from repro.serving.metrics import ServingTelemetry
from repro.serving.policy import (DeadlineCostPolicy, FifoPolicy,
                                  get_policy, ttft_slack)
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session
from repro.serving.tier_store import TierStore

PAGE = 8


@pytest.fixture(scope="module")
def tconst_setup():
    cfg = reduced(get_config("tconst_41m"), dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def lm_setup():
    cfg = reduced(get_config("llama3_405b"), dtype="float32")
    api = build_model(cfg)
    return cfg, api, api.init(jax.random.PRNGKey(0))


def _prompt(rng, cfg, n):
    return rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# 1. overtake accounting: resumes count against the head-skip budget
# ---------------------------------------------------------------------------


def test_resume_overtakes_count_toward_head_skip_budget(lm_setup):
    cfg, api, params = lm_setup
    spec = LT.LayoutSpec(kind="paged", page_size=PAGE, pool_pages=12)
    decode = build_decode(cfg, spec)
    sched = SlotScheduler(decode, params, slots=3, max_len=96,
                          chunk_size=2, tier_store=TierStore(),
                          max_head_skips=1)
    rng = np.random.RandomState(0)
    small_a = sched.submit(Session(_prompt(rng, cfg, 10),
                                   max_new_tokens=8))
    small_b = sched.submit(Session(_prompt(rng, cfg, 10),
                                   max_new_tokens=8))
    sched.step()                                 # both admitted, 6 free
    assert small_a.slot is not None and small_b.slot is not None
    big = sched.submit(Session(_prompt(rng, cfg, 60), max_new_tokens=16))
    # big needs 10 pages; spilling ONE small leaves 9 free -> head still
    # page-blocked, but the spilled session's RESUME (3 pages) fits
    sched.spill(small_a.slot)
    assert sched.pending[0] is big and sched.pending[1] is small_a
    sched.admit_pending()
    assert small_a.slot is not None              # resumed past the head
    assert sched.admit_stats[-1].source == "resume"
    assert sched._head_skips == 1                # the overtake was counted
    # budget (max_head_skips=1) is now spent: further resumes must NOT
    # overtake the still-blocked head — strict arrival order
    sched.spill(small_a.slot)
    sched.admit_pending()
    assert small_a.slot is None and big.slot is None
    # freeing the other small's pages lets the head in; budget resets
    sched.spill(small_b.slot)
    sched.admit_pending()
    assert big.slot is not None
    assert sched._head_skips == 0


def test_strict_mode_is_policy_proof(tconst_setup):
    # even a policy that always proposes the tail first cannot overtake
    # once the budget is spent: the scheduler only offers it the head
    class TailFirst(FifoPolicy):
        def order_pending(self, pending, sched):
            return list(reversed(pending))

    cfg, api, params = tconst_setup
    spec = LT.LayoutSpec(kind="paged", page_size=PAGE, pool_pages=8)
    decode = build_decode(cfg, spec)
    sched = SlotScheduler(decode, params, slots=1, max_len=64,
                          chunk_size=2, max_head_skips=0,
                          policy=TailFirst())
    rng = np.random.RandomState(1)
    first = sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=4))
    second = sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=4))
    sched.admit_pending()
    assert first.slot is not None and second.slot is None


# ---------------------------------------------------------------------------
# 2. per-session sampling chains: identity across policies and runs
# ---------------------------------------------------------------------------


def _drive(sched, sessions):
    for s in sessions:
        sched.submit(s)
    sched.run()
    return [tuple(s.tokens) for s in sessions]


def _make_sessions(cfg, n=5):
    rng = np.random.RandomState(7)
    return [Session(_prompt(rng, cfg, int(rng.randint(4, 12))),
                    max_new_tokens=int(rng.randint(4, 9)),
                    temperature=0.8, seed=100 + i) for i in range(n)]


@pytest.mark.parametrize("policy", ["fifo", "slo"])
def test_streams_identical_across_policies_through_spills(tconst_setup,
                                                          policy, request):
    cfg, api, params = tconst_setup
    decode = build_decode(cfg, LT.LayoutSpec(kind="dense"))
    # oversubscribed: 5 sessions through 2 slots with aggressive
    # preemption forces spill/resume cycles under BOTH policies
    sched = SlotScheduler(decode, params, slots=2, max_len=64,
                          chunk_size=4, tier_store=TierStore(),
                          preempt_chunks=1, policy=policy)
    streams = _drive(sched, _make_sessions(cfg))
    assert sched.spill_stats["spills"] > 0
    cache = request.config.cache
    prior = cache.get("serving_policy/streams", None)
    mine = [list(t) for t in streams]
    if prior is None:
        cache.set("serving_policy/streams", mine)
    else:
        assert mine == prior, \
            "token streams changed with the scheduling policy"


def test_streams_identical_across_runs_same_seed(tconst_setup):
    cfg, api, params = tconst_setup
    decode = build_decode(cfg, LT.LayoutSpec(kind="dense"))

    def once():
        sched = SlotScheduler(decode, params, slots=2, max_len=64,
                              chunk_size=4)
        return _drive(sched, _make_sessions(cfg, n=3))

    assert once() == once()


def test_sessions_without_seed_fall_back_to_sid_fold(tconst_setup):
    # no explicit seed: the chain derives from (scheduler seed, sid) —
    # still deterministic for a fixed sid, never slot-position-dependent
    cfg, api, params = tconst_setup
    decode = build_decode(cfg, LT.LayoutSpec(kind="dense"))
    rng = np.random.RandomState(3)
    prompt = _prompt(rng, cfg, 8)

    def run_at_slot(occupy_first):
        sched = SlotScheduler(decode, params, slots=2, max_len=64,
                              chunk_size=4, seed=9)
        if occupy_first:                   # push the probe to slot 1
            sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=20,
                                 temperature=0.9, seed=1))
        probe = Session(prompt, max_new_tokens=6, temperature=0.9)
        probe.sid = 12345                  # pin identity across runs
        sched.submit(probe)
        sched.run()
        return tuple(probe.tokens)

    assert run_at_slot(False) == run_at_slot(True)


# ---------------------------------------------------------------------------
# 3. cost model + victim selection
# ---------------------------------------------------------------------------


def test_spill_cost_scales_with_live_pages_and_readmit(lm_setup):
    cfg, api, params = lm_setup
    spec = LT.LayoutSpec(kind="paged", page_size=PAGE, pool_pages=24)
    decode = build_decode(cfg, spec)
    sched = SlotScheduler(decode, params, slots=2, max_len=128,
                          chunk_size=2)
    rng = np.random.RandomState(2)
    short = sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=4))
    long = sched.submit(Session(_prompt(rng, cfg, 60), max_new_tokens=4))
    sched.admit_pending()
    c_short = sched.spill_cost(short.slot)
    c_long = sched.spill_cost(long.slot)
    assert c_long["bytes"] > c_short["bytes"]
    # dense-LM admission is not prompt-pure: re-admission re-pays bytes
    assert c_short["readmit"] == c_short["bytes"] > 0
    assert c_long["total"] == 2 * c_long["bytes"]


def test_spill_cost_tconst_readmits_free(tconst_setup):
    cfg, api, params = tconst_setup
    decode = build_decode(cfg, LT.LayoutSpec(kind="dense"))
    sched = SlotScheduler(decode, params, slots=1, max_len=64,
                          chunk_size=2)
    rng = np.random.RandomState(2)
    s = sched.submit(Session(_prompt(rng, cfg, 8), max_new_tokens=4))
    sched.admit_pending()
    cost = sched.spill_cost(s.slot)
    assert cost["readmit"] == 0                  # admission_key: O(1) redo
    assert cost["total"] == cost["bytes"] > 0


def test_deadline_policy_spills_cheapest_and_protects_itl(lm_setup):
    cfg, api, params = lm_setup
    spec = LT.LayoutSpec(kind="paged", page_size=PAGE, pool_pages=24)
    decode = build_decode(cfg, spec)
    sched = SlotScheduler(decode, params, slots=3, max_len=128,
                          chunk_size=2, policy="slo")
    rng = np.random.RandomState(4)
    cheap = sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=4))
    costly = sched.submit(Session(_prompt(rng, cfg, 60), max_new_tokens=4))
    bound = sched.submit(Session(_prompt(rng, cfg, 6), max_new_tokens=4,
                                 slo_itl_chunks=1))
    sched.admit_pending()
    ripe = [cheap.slot, costly.slot, bound.slot]
    picks = sched.policy.select_victims(sched, ripe, 3)
    assert picks[0] == cheap.slot                # cheapest bytes first
    assert picks[-1] == bound.slot               # ITL-bound spilled last


def test_deadline_policy_orders_by_slack_then_priority():
    class Clocked:
        clock = 10

    def sess(submit, slo, prio):
        s = Session(np.ones(4, np.int32), max_new_tokens=2, priority=prio,
                    slo_ttft_chunks=slo)
        s.submit_clock = submit
        return s

    tight = sess(9, 4, 0)              # slack 3
    loose = sess(0, 30, 0)             # slack 20
    free = sess(0, None, 0)            # slack inf
    vip = sess(9, 4, 2)                # slack 3, higher priority
    order = DeadlineCostPolicy().order_pending(
        [free, loose, tight, vip], Clocked())
    assert order == [vip, tight, loose, free]
    assert ttft_slack(free, 10) == float("inf")


def test_get_policy_registry():
    assert get_policy("fifo").name == "fifo"
    assert get_policy("slo").name == "slo"
    with pytest.raises(ValueError):
        get_policy("lifo")
    with pytest.raises(ValueError):
        DeadlineCostPolicy(defer_slack=-1)


# ---------------------------------------------------------------------------
# 4. telemetry through the scheduler
# ---------------------------------------------------------------------------


def test_telemetry_tracks_every_session_to_retirement(tconst_setup):
    cfg, api, params = tconst_setup
    decode = build_decode(cfg, LT.LayoutSpec(kind="dense"))
    tel = ServingTelemetry()
    sched = SlotScheduler(decode, params, slots=2, max_len=64,
                          chunk_size=4, tier_store=TierStore(),
                          preempt_chunks=1, telemetry=tel)
    sessions = _make_sessions(cfg, n=4)
    _drive(sched, sessions)
    assert len(tel.records) == 4
    for s in sessions:
        rec = tel.records[s.sid]
        assert rec.done and rec.tokens_out == len(s.tokens)
        assert rec.ttft_chunks is not None and rec.ttft_chunks >= 1
        assert rec.queue_wait_chunks is not None
        assert rec.spills == s.spills and rec.resumes == s.resumes
    summary = tel.summary()
    assert summary["finished"] == 4
    assert summary["spills"] == sched.spill_stats["spills"] > 0
    assert len(tel.occupancy) == sched.clock

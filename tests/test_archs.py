"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the
same family (2 layers equivalent, d_model<=512, <=4 experts) and runs one
forward + one train step on CPU, asserting output shapes and no NaNs;
plus a prefill/decode consistency check of the serving path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, list_archs, reduced
from repro.models.api import build_model
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

ARCHS = [a for a in list_archs()]
B, L = 2, 32


def make_batch(cfg, key, length=L):
    batch = {"tokens": jax.random.randint(key, (B, length), 0,
                                          cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.frontend_dim),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
        batch["vision_mask"] = jnp.zeros((B, length), bool).at[
            :, :cfg.frontend_tokens].set(True)
    if cfg.is_encdec:
        batch["audio_feats"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.frontend_dim),
            jnp.float32).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = reduced(get_config(arch), dtype="float32")
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, api, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(built, arch):
    cfg, api, params = built[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = api.forward(params, batch)
    assert logits.shape == (B, L, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(built, arch):
    cfg, api, params = built[arch]
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step = make_train_step(api, opt_cfg, n_micro=2)
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    new_params, opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters must actually move
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(built, arch):
    cfg, api, params = built[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    logits, _ = api.forward(params, batch)
    n0 = 17
    pb = {k: (v[:, :n0] if k in ("tokens", "vision_mask") else v)
          for k, v in batch.items()}
    lg, cache = api.prefill(params, pb, max_len=L)
    # MoE: prefill routes 17-token groups vs the forward's 32-token groups
    # -> different capacity drops are legitimate (GShard semantics)
    tol = 0.75 if cfg.is_moe else 1e-4
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits[:, n0 - 1]), atol=tol)


@pytest.mark.parametrize("arch", ["mamba2_130m", "hymba_1_5b", "gemma3_4b",
                                  "smollm_360m", "whisper_small",
                                  "mixtral_8x22b"])
def test_decode_matches_forward(built, arch):
    """Covers ssm / hybrid / local-global / dense / enc-dec / moe decode.

    (MoE archs can diverge when a capacity drop occurs in the full forward
    — GShard semantics — so they use a looser tolerance.)"""
    cfg, api, params = built[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(4))
    logits, _ = api.forward(params, batch)
    n0 = 17
    pb = {k: (v[:, :n0] if k in ("tokens", "vision_mask") else v)
          for k, v in batch.items()}
    lg, cache = api.prefill(params, pb, max_len=L)
    tol = 0.75 if cfg.is_moe else 1e-4
    for t in range(n0, min(n0 + 6, L)):
        if bool(np.asarray(api.needs_resync(cache)).all()):
            cache = api.resync(params, cache)
        lg, cache = api.decode_step(params, cache, batch["tokens"][:, t])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits[:, t]), atol=tol)


def test_gemma3_local_global_pattern():
    from repro.models.lm import layer_windows
    cfg = get_config("gemma3_4b")
    w = layer_windows(cfg)
    assert len(w) == 34
    assert w[5] == 0 and w[11] == 0          # every 6th layer is global
    assert all(x == 1024 for i, x in enumerate(w) if i % 6 != 5)


def test_all_assigned_archs_registered():
    expected = {"mixtral_8x22b", "llama3_405b", "mamba2_130m",
                "deepseek_moe_16b", "smollm_360m", "minicpm_2b",
                "hymba_1_5b", "whisper_small", "gemma3_4b", "qwen2_vl_2b",
                "tconst_41m"}
    assert expected.issubset(set(list_archs()))


def test_full_configs_match_assignment():
    specs = {
        "mixtral_8x22b": (56, 6144, 48, 8, 32768),
        "llama3_405b": (126, 16384, 128, 8, 128256),
        "mamba2_130m": (24, 768, 1, 1, 50280),
        "deepseek_moe_16b": (28, 2048, 16, 16, 102400),
        "smollm_360m": (32, 960, 15, 5, 49152),
        "minicpm_2b": (40, 2304, 36, 36, 122753),
        "hymba_1_5b": (32, 1600, 25, 5, 32001),
        "whisper_small": (12, 768, 12, 12, 51865),
        "gemma3_4b": (34, 2560, 8, 4, 262144),
        "qwen2_vl_2b": (28, 1536, 12, 2, 151936),
    }
    for arch, (nl, d, h, kv, v) in specs.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab_size) == (nl, d, h, kv, v), arch
    assert get_config("mixtral_8x22b").n_experts == 8
    assert get_config("deepseek_moe_16b").n_experts == 64
    assert get_config("deepseek_moe_16b").n_experts_per_tok == 6
    assert get_config("deepseek_moe_16b").n_shared_experts == 2
    assert get_config("mamba2_130m").ssm_state == 128
    assert get_config("hymba_1_5b").ssm_state == 16
    assert get_config("gemma3_4b").local_global_ratio == 5

"""Decode-state sharding rules (mesh-native serving, PR 9).

Covers the pure spec computation — no device mesh needed:

* the batch-indivisible replication fallback WARNS exactly once per
  (batch, data-size) shape, and the divisible branch stays silent
  (the satellite bugfix: it used to fall back silently);
* ``decode_field_spec``'s per-field policy table: layout bookkeeping
  replicated, paged pools head-sharded with the page axis replicated,
  int8 scales riding the parent spec with the trailing 1 replicated,
  dense KV slot+head sharded, head-dim fallback when KV heads don't
  divide;
* ``MeshContext`` hashability (it keys jit caches via DecodeState aux)
  and ``build_decode``'s KV-head divisibility validation;
* ``decode_shardings`` returns a DecodeState-structured pytree of
  NamedSharding on a real (1-device) mesh.
"""
import logging

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import get_config, reduced
from repro.sharding import rules


class FakeMesh:
    """Just enough Mesh surface for the rule functions."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    def __repr__(self):
        return f"FakeMesh({self.shape})"


MESH = FakeMesh({"data": 2, "model": 4})


@pytest.fixture(autouse=True)
def _rearm_warning():
    rules._WARNED_BATCH_FALLBACK.clear()
    yield
    rules._WARNED_BATCH_FALLBACK.clear()


# ---------------------------------------------------------------------------
# warn-once replication fallback (satellite bugfix)
# ---------------------------------------------------------------------------


def test_indivisible_batch_warns_once(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.sharding"):
        assert rules._batch_divisible(3, MESH) is False
        assert rules._batch_divisible(3, MESH) is False   # same shape again
    warns = [r for r in caplog.records if "falling back to replication"
             in r.message]
    assert len(warns) == 1, "fallback must warn exactly once per shape"
    assert "3" in warns[0].getMessage() and "2" in warns[0].getMessage()


def test_distinct_shapes_warn_separately(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.sharding"):
        rules._batch_divisible(3, MESH)
        rules._batch_divisible(5, MESH)
    assert sum("falling back" in r.message for r in caplog.records) == 2


def test_divisible_batch_is_silent(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.sharding"):
        assert rules._batch_divisible(4, MESH) is True
        # batch smaller than the data axes is indivisible by definition
        assert rules._batch_divisible(1, FakeMesh({"data": 1, "model": 4}),
                                      ) is True   # dsize=1: trivially ok
    assert not caplog.records


def test_cache_spec_covers_both_branches(caplog):
    """The _cache_spec integration: divisible batch shards the slot dim,
    indivisible replicates it (and warns through the same choke point)."""
    kv = jax.ShapeDtypeStruct((4, 64, 8, 16), np.float32)
    (path, leaf), = jax.tree_util.tree_flatten_with_path({"k": kv})[0]
    with caplog.at_level(logging.WARNING, logger="repro.sharding"):
        assert rules._cache_spec(path, leaf, MESH, batch=4) == \
            P("data", None, "model", None)
        assert not caplog.records
        kv3 = jax.ShapeDtypeStruct((3, 64, 8, 16), np.float32)
        (path3, leaf3), = jax.tree_util.tree_flatten_with_path(
            {"k": kv3})[0]
        spec = rules._cache_spec(path3, leaf3, MESH, batch=3)
    assert spec == P(None, "data", "model", None)   # seq-dim fallback
    assert sum("falling back" in r.message for r in caplog.records) == 1


# ---------------------------------------------------------------------------
# decode_field_spec policy table
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,shape,kw,want", [
    # layout bookkeeping (page tables, counters): replicated
    ("layout__pages", (4, 12), dict(batch=4, baxis=0), P()),
    # shared paged pool: KV heads over model, page axis REPLICATED
    ("hist_k", (41, 8, 8, 16), dict(batch=4, pool_axis=0),
     P(None, None, "model", None)),
    # int8 pool rides the parent spec; trailing size-1 scale replicated
    ("hist_k__q", (41, 8, 8, 16), dict(batch=4, pool_axis=0),
     P(None, None, "model", None)),
    ("hist_k__scale", (41, 8, 8, 1), dict(batch=4, pool_axis=0),
     P(None, None, "model", None)),
    # dense KV: slot dim over data + KV heads over model
    ("k", (4, 128, 8, 16), dict(batch=4, baxis=0),
     P("data", None, "model", None)),
    # KV heads indivisible by model=4 -> KV replicates over model (no
    # head-dim fallback: that would split the QK/AV contractions)
    ("k", (4, 128, 2, 16), dict(batch=4, baxis=0),
     P("data", None, None, None)),
    # MQA (1 KV head): same — replicated over model, data split only
    ("k", (4, 128, 1, 16), dict(batch=4, baxis=0),
     P("data", None, None, None)),
    # indivisible slot dim -> replicated batch, heads still sharded
    ("k", (3, 128, 8, 16), dict(batch=3, baxis=0),
     P(None, None, "model", None)),
    # plain bookkeeping: slot dim over data only
    ("tokens", (4, 128), dict(batch=4, baxis=0), P("data", None)),
    ("len", (4,), dict(batch=4, baxis=0), P("data")),
    # no slot dim (shared field): fully replicated
    ("step", (2,), dict(batch=4), P(None)),
])
def test_decode_field_spec_table(name, shape, kw, want):
    assert rules.decode_field_spec(name, shape, MESH, **kw) == want


def test_decode_field_spec_divides(caplog):
    """Every sharded dim divides evenly by its axis size — the invariant
    behind 'same path, just placed'."""
    for name, shape, kw in [
        ("k", (8, 96, 4, 32), dict(batch=8, baxis=0)),
        ("hist_v", (17, 8, 4, 32), dict(batch=8, pool_axis=0)),
        ("ssm", (2, 8, 4, 16, 8), dict(batch=8, baxis=1)),
        ("conv", (2, 8, 3, 64), dict(batch=8, baxis=1)),
    ]:
        spec = rules.decode_field_spec(name, shape, MESH, **kw)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert shape[dim] % size == 0, (name, shape, spec)


# ---------------------------------------------------------------------------
# MeshContext / decode_shardings on a real mesh
# ---------------------------------------------------------------------------


def _one_device_mesh():
    grid = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return jax.sharding.Mesh(grid, ("data", "model"))


def test_mesh_context_hashable_and_normalised():
    mesh = _one_device_mesh()
    ctx = rules.as_mesh_context(mesh)
    assert isinstance(ctx, rules.MeshContext)
    assert rules.as_mesh_context(ctx) is ctx
    assert rules.as_mesh_context(None) is None
    assert hash(ctx) == hash(rules.MeshContext(mesh))
    assert ctx == rules.MeshContext(mesh)
    assert ctx.data_shards == 1 and ctx.model_shards == 1


def test_build_decode_rejects_indivisible_model_axis():
    from repro.models.api import build_decode
    cfg = reduced(get_config("tconst_41m"), dtype="float32")
    assert cfg.n_kv_heads % 3 != 0
    with pytest.raises(ValueError, match="model axis"):
        build_decode(cfg, mesh=FakeMesh({"data": 1, "model": 3}))


def test_decode_shardings_structure():
    cfg = reduced(get_config("tconst_41m"), dtype="float32")
    mesh = _one_device_mesh()
    sh = rules.decode_shardings(cfg, mesh, slots=2, max_len=64)
    leaves = jax.tree_util.tree_leaves(sh)
    assert leaves and all(isinstance(s, NamedSharding) for s in leaves)
    # size-1 axes: every sharding is (trivially) a single-device
    # placement, so jit could take these as in_shardings verbatim
    assert all(s.num_devices == 1 for s in leaves)

"""Mesh-native decode (PR 9): the SAME decode path on a (data, model)
device mesh.

Everything except the argparse validation needs 8 devices — CI's
``sharded-cpu`` job provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; under the plain
tier-1 run these tests skip (the conftest deliberately keeps the single
real CPU device).

* **stream identity** — greedy decode on a 2x4 mesh is token-identical
  to the 1-device run across {tconst, tlin, lm, encdec} x
  {dense, paged, paged_int8} (the acceptance bar: sharding is a
  placement decision, never a numerics one);
* **no pool all-gather** — the compiled sharded step never gathers a
  KV pool: head-sharded QK/AV runs on local head slices (shard_map),
  so any all-gather in the HLO is bookkeeping-sized;
* **byte accounting** — ``kv_bytes``/``assigned_kv_bytes`` report
  GLOBAL bytes (identical meshed vs unmeshed — the satellite
  regression), ``per_device_kv_bytes`` reports the largest shard
  (global / 8 when everything splits);
* **serve --mesh validation** — bad geometries die in argparse, not in
  a shape crash.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_decode_mesh
from repro.models.api import build_decode
from repro.serving.engine import Engine
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session

import parity

requires_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

B, L, GEN, MAX_LEN, PAGE = 2, 16, 6, 64, parity.PAGE


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    return make_decode_mesh(2, 4)


@pytest.fixture(scope="module")
def setups():
    return {fam: parity.family(fam)
            for fam in ("tconst", "tlin", "lm", "encdec")}


def _spec(kind):
    # pool_pages=None: this suite sizes the pool from slots (the mesh
    # split is what's under test, not pool pressure).
    return parity.layout_spec(kind, pool_pages=None)


def _batch(cfg):
    batch = {"tokens": jnp.ones((B, L), jnp.int32)}
    if cfg.is_encdec:
        batch["audio_feats"] = jnp.zeros(
            (B, cfg.encoder_seq, cfg.frontend_dim), jnp.float32)
    return batch


def _replicated(params, mesh):
    return jax.device_put(params, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))


# ---------------------------------------------------------------------------
# stream identity: {family} x {layout}, meshed vs 1 device
# ---------------------------------------------------------------------------


@requires_mesh
@pytest.mark.parametrize("kind", ["dense", "paged", "paged_int8"])
@pytest.mark.parametrize("family", ["tconst", "tlin", "lm", "encdec"])
def test_stream_identical_to_1device(family, kind, mesh, setups):
    cfg, api, params = setups[family]
    batch = _batch(cfg)
    ref = Engine(api, params, max_len=MAX_LEN,
                 layout=_spec(kind)).generate(batch, GEN)
    out = Engine(api, _replicated(params, mesh), max_len=MAX_LEN,
                 layout=_spec(kind), mesh=mesh).generate(batch, GEN)
    np.testing.assert_array_equal(ref, out)


# ---------------------------------------------------------------------------
# compiled step: no KV-pool all-gather
# ---------------------------------------------------------------------------


@requires_mesh
def test_sharded_paged_step_has_no_pool_allgather(mesh, setups):
    """Head-sharded attention runs on local head slices — the only
    all-gathers a sharded paged step may contain are bookkeeping-sized
    (page tables, per-slot lengths), orders of magnitude below the
    pool.  A pool gather would defeat the entire memory split."""
    cfg, api, params = setups["tlin"]
    decode = build_decode(cfg, _spec("paged"), mesh=mesh)
    params = _replicated(params, mesh)
    _, state = jax.jit(lambda p, b: decode.prefill(p, b, MAX_LEN))(
        params, _batch(cfg))
    token = jnp.ones((B,), jnp.int32)
    hlo = jax.jit(decode.raw_step).lower(params, state, token) \
        .compile().as_text()
    pool_elems = min(int(np.prod(leaf.shape))
                     for leaf in jax.tree_util.tree_leaves(state.kv)
                     if leaf.ndim >= 4 and leaf.size > 10_000)
    for line in hlo.splitlines():
        if "all-gather(" not in line and "all-gather-start(" not in line:
            continue
        shapes = re.findall(r"\w+\[([\d,]+)\]",
                            line.split("all-gather")[0])
        for dims in shapes:
            elems = int(np.prod([int(d) for d in dims.split(",")]))
            assert elems < pool_elems / 8, \
                f"pool-sized all-gather in the sharded step: {line.strip()}"


# ---------------------------------------------------------------------------
# byte accounting: global vs per-device (satellite regression)
# ---------------------------------------------------------------------------


@requires_mesh
def test_kv_bytes_global_and_per_device(mesh, setups):
    cfg, api, params = setups["tconst"]
    ref = build_decode(cfg).init_state(B, MAX_LEN)
    state = build_decode(cfg, mesh=mesh).init_state(B, MAX_LEN)
    # GLOBAL bytes are placement-invariant
    assert state.kv_bytes() == ref.kv_bytes()
    assert state.assigned_kv_bytes() == ref.assigned_kv_bytes()
    # tconst dense KV splits fully: slots over data (2) x heads over
    # model (4) -> each device holds 1/8th
    assert state.kv_bytes() == 8 * state.per_device_kv_bytes()
    # unmeshed: per-device IS global
    assert ref.per_device_kv_bytes() == ref.kv_bytes()


@requires_mesh
def test_scheduler_reports_global_bytes_meshed(mesh, setups):
    """assigned_kv_bytes through the scheduler: identical meshed vs
    unmeshed after the same admissions (a sharded pool must not report
    one shard's buffer)."""
    cfg, api, params = setups["tlin"]
    prompt = np.arange(1, 18, dtype=np.int32)

    def admit(mesh_arg, p):
        sched = SlotScheduler(
            build_decode(cfg, _spec("paged"), mesh=mesh_arg), p,
            slots=2, max_len=MAX_LEN, chunk_size=4)
        sched.submit(Session(prompt.copy(), max_new_tokens=4))
        sched.admit_pending()
        return sched

    ref = admit(None, params)
    meshed = admit(mesh, _replicated(params, mesh))
    assert meshed.assigned_kv_bytes() == ref.assigned_kv_bytes() > 0
    assert meshed.kv_bytes() == ref.kv_bytes()
    assert meshed.per_device_kv_bytes() < meshed.kv_bytes()


# ---------------------------------------------------------------------------
# serve --mesh validation (no mesh entry needed)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mesh_arg", ["bogus", "2x", "0x4", "3x5"])
def test_serve_mesh_validation_dies_in_argparse(mesh_arg, capsys):
    from repro.launch import serve
    with pytest.raises(SystemExit) as exc:
        serve.main(["--arch", "tconst-41m", "--reduced",
                    "--mesh", mesh_arg])
    assert exc.value.code == 2            # argparse error, not a crash
    err = capsys.readouterr().err
    assert "--mesh" in err


@requires_mesh
def test_serve_mesh_rejects_indivisible_kv_heads(capsys):
    from repro.launch import serve
    with pytest.raises(SystemExit) as exc:
        serve.main(["--arch", "tconst-41m", "--reduced", "--mesh", "1x8"])
    assert exc.value.code == 2
    assert "KV heads" in capsys.readouterr().err

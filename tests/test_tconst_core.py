"""The paper's core invariants.

1. Decode path == training forward (prefill + O(1) cache-hit steps +
   periodic resync reproduce the chunked teacher-forced logits exactly).
2. Eq. (7): the KV cache is exactly 2B(H+1)W_oh*d + 2B(H+2)W_og*d per
   block and INDEPENDENT of sequence length.
3. Amortized schedule: exactly one cache miss per W_og generated tokens.
4. TLinFormer-mode cache grows O(N); TConst does not.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, TConstConfig
from repro.core import tconst as T


def tiny_cfg(**kw):
    base = dict(name="tiny", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=97, n_layers=8, dtype="float32",
                attention_mode="tconst",
                tconst=TConstConfig(w_oh=8, w_og=8, h=2))
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    params = T.init_tconst_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    logits, _ = T.tconst_forward(params, tokens, cfg)
    return cfg, params, tokens, logits


def test_train_forward_finite(setup):
    cfg, params, tokens, logits = setup
    assert logits.shape == (2, 32, 97)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("n0", [5, 8, 9, 16, 21, 31])
def test_prefill_matches_train_forward(setup, n0):
    cfg, params, tokens, logits = setup
    lg, cache = T.prefill(params, tokens[:, :n0], cfg, max_len=64)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, n0 - 1]),
                               atol=1e-4)


def test_decode_with_resync_matches_train_forward(setup):
    cfg, params, tokens, logits = setup
    lg, cache = T.prefill(params, tokens[:, :5], cfg, max_len=64)
    n_miss = 0
    for t in range(5, tokens.shape[1]):
        if int(cache["gen_len"][0]) == cfg.tconst.w_og:
            cache = T.resync(params, cache, cfg)
            n_miss += 1
        lg, cache = T.decode_step(params, cache, tokens[:, t], cfg)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(logits[:, t]), atol=1e-4)
    # 27 decode steps from gen_len=5: window fills at t=8,16,24 -> 3 misses
    assert n_miss == 3


def test_kv_cache_matches_eq7_and_is_constant_in_N(setup):
    cfg, params, tokens, _ = setup
    tc = cfg.tconst
    d = cfg.d_model
    n_blocks = cfg.tconst_blocks
    kv_frac = cfg.n_kv_heads * cfg.resolved_head_dim / d
    for B, max_len in [(2, 64), (2, 4096), (4, 64)]:
        cache = T.init_tconst_cache(cfg, B, max_len)
        got = T.kv_cache_bytes(cache)
        # Eq. (7) per block, adapted for GQA (K/V stored at kv_heads width)
        itemsize = jnp.dtype(cfg.dtype).itemsize
        expect = n_blocks * itemsize * B * d * kv_frac * 2 * (
            (tc.h + 1) * tc.w_oh + (tc.h + 2) * tc.w_og)
        assert got == int(expect), (got, expect)
    c64 = T.kv_cache_bytes(T.init_tconst_cache(cfg, 2, 64))
    c1m = T.kv_cache_bytes(T.init_tconst_cache(cfg, 2, 1 << 20))
    assert c64 == c1m, "KV cache must be O(1) in sequence length"


def test_tlin_cache_grows_linearly():
    cfg = tiny_cfg(attention_mode="tlin")
    c1 = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 128, mode="tlin"))
    c2 = T.kv_cache_bytes(T.init_tconst_cache(cfg, 1, 256, mode="tlin"))
    assert c2 > c1, "TLinFormer history KV must grow with max_len"


def test_tlin_decode_matches_train_forward():
    cfg = tiny_cfg(attention_mode="tlin", n_layers=4)
    params = T.init_tconst_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
    logits, _ = T.tconst_forward(params, tokens, cfg, mode="tlin")
    lg, cache = T.prefill(params, tokens[:, :17], cfg, max_len=32,
                          mode="tlin")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, 16]),
                               atol=1e-4)
    for t in range(17, 24):
        if int(cache["gen_len"][0]) == cfg.tconst.w_og:
            cache = T.resync(params, cache, cfg, mode="tlin")
        lg, cache = T.decode_step(params, cache, tokens[:, t], cfg,
                                  mode="tlin")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=1e-4)


def test_gradients_flow_through_chunked_forward():
    cfg = tiny_cfg(n_layers=4)
    params = T.init_tconst_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)

    def loss(p):
        lg, _ = T.tconst_forward(p, tokens, cfg)
        return jnp.mean((lg.astype(jnp.float32)) ** 2)

    grads = jax.grad(loss)(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    # every parameter must receive gradient (topology uses all weights)
    nonzero = [float(jnp.max(jnp.abs(g))) > 0 for g in leaves]
    assert sum(nonzero) >= len(nonzero) - 1   # allow e.g. padded corner


def test_needs_resync_flag():
    from repro.models.api import build_model
    cfg = tiny_cfg()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    tokens = jnp.ones((1, 8), jnp.int32)
    _, cache = api.prefill(params, {"tokens": tokens}, 64)
    assert bool(api.needs_resync(cache).all())   # gen window exactly full
    cache = api.resync(params, cache)
    assert not bool(api.needs_resync(cache).any())

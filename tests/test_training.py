"""Training substrate: optimizer, schedules, grad accumulation,
checkpointing, and a small end-to-end convergence run."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduced
from repro.data.pipeline import DataConfig, batches
from repro.models.api import build_model
from repro.training.checkpoint import restore_pytree, save_pytree
from repro.training.optim import AdamWConfig, adamw_update, global_norm, \
    init_opt_state
from repro.training.schedules import constant, warmup_cosine, wsd
from repro.training.train_step import make_train_step


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg,
                                        jnp.ones(()))
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(params, cfg)
    grads = {"w": jnp.full((4,), 1e6)}
    _, _, info = adamw_update(params, grads, state, cfg, jnp.ones(()))
    assert float(info["grad_norm"]) > 1e5     # reported pre-clip


def test_schedules_shapes():
    s = warmup_cosine(10, 100)
    assert float(s(jnp.array(0))) == 0.0
    assert abs(float(s(jnp.array(10))) - 1.0) < 1e-6
    assert float(s(jnp.array(100))) < 0.2
    w = wsd(10, 80, 10)
    assert abs(float(w(jnp.array(50))) - 1.0) < 1e-6   # stable plateau
    assert float(w(jnp.array(100))) < 0.1              # decayed
    assert float(constant()(jnp.array(123))) == 1.0


def test_microbatching_matches_full_batch():
    """Grad accumulation over n_micro microbatches == single big batch."""
    cfg = reduced(get_config("smollm_360m"), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                          0, cfg.vocab_size)}
    outs = []
    for n_micro in (1, 2, 4):
        opt = init_opt_state(params, opt_cfg)
        step = make_train_step(api, opt_cfg, n_micro=n_micro)
        new_p, _, m = step(params, opt, batch)
        outs.append((new_p, float(m["loss"])))
    for (p2, l2) in outs[1:]:
        assert abs(outs[0][1] - l2) < 1e-5
        for a, b in zip(jax.tree_util.tree_leaves(outs[0][0]),
                        jax.tree_util.tree_leaves(p2)):
            # accumulation order differs between the scan and no-scan
            # paths; AdamW's rsqrt amplifies ~1e-7 grad noise post-update
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4)


@pytest.mark.slow
def test_tconst_training_converges():
    """End-to-end: reduced paper model on synthetic data; loss must drop
    by a clear margin within 80 steps."""
    cfg = reduced(get_config("tconst_41m"), dtype="float32", vocab_size=256)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(api, opt_cfg, warmup_cosine(8, 80),
                                   n_micro=1))
    dc = DataConfig(vocab_size=256, seq_len=32, batch_size=8, seed=0)
    losses = []
    for b in batches(dc, steps=80):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"][:, :32])})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("smollm_360m"), dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ck.msgpack")
    save_pytree(params, path)
    restored = restore_pytree(params, path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    dc = DataConfig(vocab_size=128, seq_len=16, batch_size=2, seed=3)
    a = next(iter(batches(dc, epoch=1)))
    b = next(iter(batches(dc, epoch=1)))
    c = next(iter(batches(dc, epoch=2)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).any()
    assert a["tokens"].shape == (2, 17)
    assert a["tokens"].max() < 128

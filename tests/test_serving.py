"""Serving engine: schedule correctness (the paper's amortized-O(1)
pattern), cache accounting, and greedy/temperature generation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.serving.engine import Engine


def _engine(mode="tconst", temp=0.0):
    cfg = reduced(get_config("tconst_41m"), dtype="float32",
                  attention_mode=mode)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, Engine(api, params, max_len=128, sample_temperature=temp)


def test_resync_schedule_is_periodic():
    cfg, eng = _engine()
    out = eng.generate({"tokens": jnp.ones((2, 12), jnp.int32)}, 30,
                       record_stats=True)
    assert out.shape == (2, 30)
    kinds = [s.kind for s in eng.stats]
    assert kinds[0] == "prefill"
    # prompt 12 -> gen_len starts at 12 % 8 = 4; misses when window fills
    miss_idx = [i for i, k in enumerate(kinds) if k == "miss"]
    assert len(miss_idx) >= 3
    gaps = np.diff(miss_idx)
    assert all(g == gaps[0] for g in gaps), "misses must be periodic"
    assert gaps[0] == cfg.tconst.w_og + 1       # w_og hits + 1 miss


def test_generation_deterministic_greedy():
    _, e1 = _engine()
    _, e2 = _engine()
    p = {"tokens": jnp.ones((1, 9), jnp.int32)}
    np.testing.assert_array_equal(e1.generate(p, 20), e2.generate(p, 20))


def test_temperature_sampling_varies():
    _, eng = _engine(temp=1.5)
    p = {"tokens": jnp.ones((1, 9), jnp.int32)}
    a = eng.generate(p, 20)
    b = eng.generate(p, 20)
    assert (a != b).any()


def test_cache_bytes_excludes_token_buffer():
    cfg, eng = _engine()
    small = eng.cache_bytes(1)
    eng2 = Engine(build_model(cfg), None, max_len=1 << 16)  # params unused
    eng2.api = eng.api
    assert small == Engine(eng.api, None, max_len=1 << 16).cache_bytes(1), \
        "KV-cache accounting must be independent of the id-buffer length"


def test_generation_continues_across_many_resyncs():
    _, eng = _engine()
    out = eng.generate({"tokens": jnp.ones((1, 8), jnp.int32)}, 50,
                       record_stats=True)
    assert out.shape == (1, 50)
    assert out.dtype == np.int32 and (out >= 0).all()
    kinds = [s.kind for s in eng.stats]
    # prompt 8 fills the window at prefill -> resync before decode 1,
    # then every w_og=8 decode steps: 1 + 48 // 8 = 7
    assert kinds.count("miss") == 7

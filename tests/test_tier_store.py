"""Session tiering: the TierStore + spill / resume / retire / re-admit.

Four concerns:

1. **TierStore mechanics** — LRU eviction under a byte capacity, pin
   semantics (pinned entries survive over capacity without a disk tier;
   demote-but-never-drop with one), the mmap'd disk tier (demotion,
   promotion, durable re-indexing), and content-addressed no-rewrite
   demotion.
2. **Snapshot/restore** — ``DecodeState.snapshot_slot`` /
   ``restore_slot`` round-trips a slot bit-exactly into a DIFFERENT
   slot, in the physical representation (int8 stays quantized).
3. **Spill/resume parity** — oversubscribed scheduling (sessions >>
   slots, preemptive spilling at chunk boundaries) streams token-
   identically to a never-spilled run across
   ``{dense, paged, int8, paged_int8} x {tconst, lm, encdec}``,
   including spills landing mid-page, resume into a different slot,
   and a store squeezed down to LRU-evicting admission entries while
   pinned session snapshots survive.
4. **Store-backed admission** — refcount-0 prefix pages retire INTO
   the store and are re-adopted without re-forwarding the prefix (the
   regression: they used to leave the content map at recycle), and a
   tconst prompt whose admission snapshot is resident re-admits with
   ZERO forward compute — no prefill call, no ``dot_general`` anywhere
   in the restore program (the O(1) re-admission acceptance bar).
"""
import jax
import numpy as np
import pytest

from repro.models.api import build_decode
from repro.serving.scheduler import SlotScheduler
from repro.serving.session import Session
from repro.serving.tier_store import (Blob, TierStore,
                                      flatten_slot_snapshot,
                                      unflatten_slot_snapshot)

import parity

PAGE = 8

# family fixtures / extras come from tests/parity.py (this suite's "lm"
# is the MQA reduction); the 8-token pages make spill points land
# mid-page, so the layout spec stays local
_extras = parity.extras_for


@pytest.fixture(scope="module")
def tconst_setup():
    return parity.family("tconst")


@pytest.fixture(scope="module")
def tlin_setup():
    return parity.family("tlin")


@pytest.fixture(scope="module")
def lm_setup():
    return parity.family("lm_mqa")


@pytest.fixture(scope="module")
def encdec_setup():
    return parity.family("encdec")


def _spec(kind):
    return parity.layout_spec(kind, page_size=PAGE, pool_pages=40)


def _prompts(cfg, n, seed=3):
    # lengths straddle page boundaries so spill points land mid-page
    return parity.make_prompts(cfg, [9 + 4 * i for i in range(n)], seed)


def _blob(nbytes, fill=0):
    return Blob({"x": np.full((nbytes,), fill, np.uint8)}, {"fill": fill})


# ---------------------------------------------------------------------------
# 1. TierStore mechanics
# ---------------------------------------------------------------------------


def test_store_lru_eviction_order_and_stats():
    st = TierStore(capacity_bytes=256)
    ka, kb, kc = b"a" * 20, b"b" * 20, b"c" * 20
    st.put(ka, _blob(100, 1))
    st.put(kb, _blob(100, 2))
    assert st.get(ka).meta["fill"] == 1          # LRU-touch: a now newest
    assert kb in st and ka in st                 # contains: no LRU touch
    st.put(kc, _blob(100, 3))                    # over capacity: b evicts
    assert kb not in st and ka in st and kc in st
    assert st.get(kb) is None
    assert st.stats["evictions"] == 1 and st.stats["misses"] == 1
    assert st.occupancy_bytes == 200 and len(st) == 2
    assert st.pop(ka).meta["fill"] == 1
    assert ka not in st and len(st) == 1


def test_store_pin_survives_capacity_without_disk_tier():
    st = TierStore(capacity_bytes=64)
    kp, kv = b"p" * 20, b"v" * 20
    st.put(kp, _blob(100, 7), pin=True)          # alone it exceeds capacity
    assert kp in st                              # pinned: kept over capacity
    st.put(kv, _blob(100, 8))
    assert kv not in st and kp in st             # unpinned victim dropped
    st.unpin(kp)
    st.put(kv, _blob(100, 8))                    # both now unpinned and each
    assert kp not in st and kv not in st         # over capacity: both evict


def test_store_disk_tier_demotes_promotes_and_reindexes(tmp_path):
    st = TierStore(capacity_bytes=128, spill_dir=str(tmp_path / "tier"))
    ka, kb = b"a" * 20, b"b" * 20
    payload = np.arange(100, dtype=np.uint8)
    st.put(ka, Blob({"x": payload}, {"tag": "first"}), pin=True)
    st.put(kb, _blob(100, 2))                    # demotes a (pinned is ok
    assert st.stats["demotions"] == 1            # WITH a disk tier below)
    assert ka in st and st.disk_bytes == 100
    got = st.get(ka)                             # promotion from disk
    assert st.stats["promotions"] == 1
    np.testing.assert_array_equal(np.asarray(got.arrays["x"]), payload)
    assert got.meta["tag"] == "first"
    # demotion of a key already on disk skips the rewrite
    st.get(kb)
    st.put(b"c" * 20, _blob(100, 3))
    assert st.stats["demotions"] >= 2
    # a spill dir is durable: a fresh store re-indexes it
    st2 = TierStore(capacity_bytes=128, spill_dir=str(tmp_path / "tier"))
    assert ka in st2
    np.testing.assert_array_equal(
        np.asarray(st2.get(ka).arrays["x"]), payload)


def test_put_under_all_pinned_over_capacity_pressure():
    st = TierStore(capacity_bytes=128)           # no disk tier
    keys = [bytes([65 + i]) * 20 for i in range(3)]
    for i, k in enumerate(keys):
        st.put(k, _blob(100, i), pin=True)
    # every resident entry is pinned and RAM is 300/128 bytes: the
    # eviction walk must terminate (skip-all break) dropping nothing
    assert all(k in st for k in keys)
    assert st.occupancy_bytes == 300
    assert st.stats["evictions"] == 0 and st.stats["puts"] == 3
    ku = b"u" * 20
    st.put(ku, _blob(50, 9))            # unpinned newcomer: sole victim
    assert ku not in st
    assert st.stats["evictions"] == 1
    assert all(k in st for k in keys) and st.occupancy_bytes == 300
    assert st.get(keys[1]).meta["fill"] == 1     # content intact
    # dropping the last pin evicts the former pin-squatter eagerly; the
    # next put then has no excuse to keep the unpinned newcomer either
    st.unpin(keys[0])
    st.put(ku, _blob(50, 9))
    assert keys[0] not in st and ku not in st    # both unpinned: evicted
    assert keys[1] in st and keys[2] in st       # still pinned: kept
    assert st.stats["evictions"] == 3


def test_promote_on_access_keeps_eviction_order_stable(tmp_path):
    st = TierStore(capacity_bytes=250, spill_dir=str(tmp_path / "tier"))
    ka, kb, kc, kd = (x * 20 for x in (b"a", b"b", b"c", b"d"))
    st.put(ka, _blob(100, 1))
    st.put(kb, _blob(100, 2))
    st.put(kc, _blob(100, 3))                    # 300/250: a demotes
    assert st.stats["demotions"] == 1 and st.disk_bytes == 100
    assert ka in st._disk and kb not in st._disk
    got = st.get(ka)                             # promote-on-access
    assert got.meta["fill"] == 1
    assert st.stats["promotions"] == 1
    # the promotion's own capacity pass evicted in LRU order: b (the
    # oldest resident) demoted — NEVER the just-promoted a, nor c
    # (white-box peek at the tier maps: __contains__ spans both tiers)
    assert st.stats["demotions"] == 2
    assert kb in st._disk and ka in st._ram and kc in st._ram
    # and a now sits at the young end of the LRU: the next pressure
    # put demotes c, not the freshly accessed a
    st.put(kd, _blob(100, 4))
    assert st.stats["demotions"] == 3
    assert kc in st._disk and ka in st._ram and kd in st._ram
    assert st.get(ka) is not None and st.stats["promotions"] == 1


def test_flatten_unflatten_snapshot_roundtrip():
    snap = {"bookkeeping": {"pos": np.array([3])},
            "kv": {"ctx_k": np.zeros((1, 2, 4), np.float32)}}
    blob = flatten_slot_snapshot(snap, {"kind": "test"})
    blob.arrays["logits"] = np.ones((7,), np.float32)   # unprefixed extra
    bk, kv, meta = unflatten_slot_snapshot(blob)
    assert set(bk) == {"pos"} and set(kv) == {"ctx_k"}
    assert meta["kind"] == "test" and "logits" not in bk and "logits" not in kv


# ---------------------------------------------------------------------------
# 2. DecodeState.snapshot_slot / restore_slot round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "int8"])
def test_snapshot_restores_into_different_slot_bit_exact(tconst_setup, kind):
    """Slot 0's snapshot restored into slot 1 reproduces slot 0's rows
    bit-exactly in the PHYSICAL representation (int8: the quantized
    payload and scales themselves round-trip, no re-quantization)."""
    cfg, api, params = tconst_setup
    dec = build_decode(cfg, _spec(kind))
    sched = SlotScheduler(dec, params, slots=2, max_len=96, chunk_size=4)
    sched.submit(Session(_prompts(cfg, 1)[0], max_new_tokens=5))
    sched.run()                       # slot 0 holds a real decoded state
    snap = jax.device_get(sched.state.snapshot_slot(0))
    state = sched.state.restore_slot(1, jax.device_get(snap))
    for part in ("bookkeeping", "kv"):
        src = snap[part]
        for name, row in src.items():
            arrs = getattr(state, part)
            if part == "bookkeeping":
                ax = state.axes[name]
            else:
                ax = state.layout._axis(name, state.axes)
            got = np.take(np.asarray(arrs[name]), [1], axis=ax)
            np.testing.assert_array_equal(got, np.asarray(row), err_msg=name)


# ---------------------------------------------------------------------------
# 3. spill / resume stream parity (oversubscribed), layouts x families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged", "int8", "paged_int8"])
@pytest.mark.parametrize("family", ["tconst", "tlin", "lm", "encdec"])
def test_spill_resume_token_identical(family, kind, request):
    """4 sessions / 2 slots with preemptive spilling every chunk: every
    stream matches the same layout's never-spilled run exactly and every
    excess session completes >= 1 full spill->resume cycle.  Prompt
    lengths straddle page boundaries, so the spill points land mid-page
    (and, with gen=8 vs chunk=4, mid-generation between prefill-chunk
    boundaries)."""
    cfg, api, params = request.getfixturevalue(f"{family}_setup")
    prompts = _prompts(cfg, 4)

    def run(slots, store=None, preempt=None):
        sched = SlotScheduler(build_decode(cfg, _spec(kind)), params,
                              slots=slots, max_len=96, chunk_size=4,
                              prefix_sharing=kind.startswith("paged"),
                              tier_store=store, preempt_chunks=preempt)
        sessions = [sched.submit(Session(
            p, max_new_tokens=8, extras=_extras(cfg)))
            for p in prompts]
        sched.run()
        return sched, sessions

    _, ref = run(slots=4)
    store = TierStore(capacity_bytes=1 << 30)
    sched, spl = run(slots=2, store=store, preempt=1)
    parity.assert_streams_equal([r.tokens for r in ref],
                                [s.tokens for s in spl],
                                f"spill/resume {family}/{kind}")
    # >= 1 full cycle per excess session (4 sessions - 2 slots = 2)
    assert sum(1 for s in spl if s.resumes >= 1) >= 2
    assert sched.spill_stats["spills"] == sched.spill_stats["resumes"] > 0
    resumes = [a for a in sched.admit_stats if a.source == "resume"]
    assert resumes and all(a.forward_tokens == 0 for a in resumes)
    assert not store.pinned_keys()     # every spill was resumed + unpinned
    if sched._paged:         # pure tconst pages nothing: no pool to check
        assert (sched.page_refcounts() == 0).all()
        assert len(sched.free_pages) == 40


@pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")
@pytest.mark.parametrize("kind", ["dense", "paged", "paged_int8"])
def test_spill_resume_meshed_bit_identical(kind, tlin_setup):
    """Spill -> resume on a 2x4 device mesh: snapshots gather to host
    per-shard, restores land with the SAME shardings, and every stream
    is bit-identical to the single-device oversubscribed run — the
    PR-6/7 tier-store machinery works verbatim under sharding.  tlin:
    the family whose KV genuinely lives in pool pages."""
    from repro.launch.mesh import make_decode_mesh

    cfg, api, params = tlin_setup
    prompts = _prompts(cfg, 4)
    mesh = make_decode_mesh(2, 4)
    meshed_params = jax.device_put(params, jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec()))

    def run(mesh_arg, p):
        sched = SlotScheduler(build_decode(cfg, _spec(kind), mesh=mesh_arg),
                              p, slots=2, max_len=96, chunk_size=4,
                              tier_store=TierStore(capacity_bytes=1 << 30),
                              preempt_chunks=1)
        sessions = [sched.submit(Session(q, max_new_tokens=8))
                    for q in prompts]
        sched.run()
        return sched, sessions

    ref_sched, ref = run(None, params)
    sched, out = run(mesh, meshed_params)
    assert sched.spill_stats["spills"] == sched.spill_stats["resumes"] > 0
    parity.assert_streams_equal([r.tokens for r in ref],
                                [s.tokens for s in out],
                                f"meshed spill/resume {kind}")
    # the byte accounting stays GLOBAL under the sharded pool
    assert sched.kv_bytes() == ref_sched.kv_bytes()
    assert sched.spill_stats["spilled_bytes"] == \
        ref_sched.spill_stats["spilled_bytes"]


def test_manual_spill_resumes_into_different_slot(tconst_setup):
    """Deterministic slot migration: spill A out of slot 0, occupy slot
    0 with another session, and A's resume must land in slot 1 with the
    stream still exact."""
    cfg, api, params = tconst_setup
    pa, pb = _prompts(cfg, 2, seed=5)
    store = TierStore()
    sched = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                          slots=2, max_len=96, chunk_size=4,
                          tier_store=store)
    sa = sched.submit(Session(pa, max_new_tokens=10))
    sched.step()                                 # A decodes in slot 0
    assert sa.slot == 0 and len(sa.tokens) == 5
    key = sched.spill(0)
    assert sa.slot is None and sa.snap_key == key
    assert key in store and key in store.pinned_keys()
    sb = sched.submit(Session(pb, max_new_tokens=4))
    sched.pending.rotate(-1)                     # B ahead of A: B gets slot 0
    sched.admit_pending()
    assert sb.slot == 0                          # slot 0 taken before resume
    assert sa.slot == 1 and sa.resumes == 1      # A migrated to slot 1
    sched.run()
    ref = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                        slots=2, max_len=96, chunk_size=4)
    ra = ref.submit(Session(pa, max_new_tokens=10))
    ref.run()
    assert sa.tokens == ra.tokens


def test_tight_store_capacity_keeps_pinned_spills_exact(tconst_setup):
    """A store squeezed far below the working set LRU-evicts unpinned
    admission entries, but pinned session snapshots survive (no disk
    tier) and parity still holds."""
    cfg, api, params = tconst_setup
    prompts = _prompts(cfg, 3, seed=7)

    def run(slots, store=None, preempt=None):
        sched = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                              slots=slots, max_len=96, chunk_size=4,
                              prefix_sharing=True, tier_store=store,
                              preempt_chunks=preempt)
        ss = [sched.submit(Session(p, max_new_tokens=8)) for p in prompts]
        sched.run()
        return [s.tokens for s in ss]

    ref = run(slots=3)
    store = TierStore(capacity_bytes=4096)       # << one slot snapshot
    assert run(slots=1, store=store, preempt=1) == ref
    assert store.stats["evictions"] > 0          # admission entries squeezed
    assert not store.pinned_keys()               # every spill resumed


def test_disk_tier_spill_resume_roundtrip(tconst_setup, tmp_path):
    """With a spill directory, a squeezed RAM tier demotes snapshots to
    disk and resumes promote them back — streams stay exact and bytes
    really land on disk."""
    cfg, api, params = tconst_setup
    prompts = _prompts(cfg, 3, seed=8)

    def run(slots, store=None, preempt=None):
        sched = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                              slots=slots, max_len=96, chunk_size=4,
                              tier_store=store, preempt_chunks=preempt)
        ss = [sched.submit(Session(p, max_new_tokens=8)) for p in prompts]
        sched.run()
        return [s.tokens for s in ss]

    ref = run(slots=3)
    store = TierStore(capacity_bytes=4096, spill_dir=str(tmp_path / "t"))
    assert run(slots=1, store=store, preempt=1) == ref
    assert store.stats["demotions"] > 0 and store.stats["promotions"] > 0
    assert any((tmp_path / "t").iterdir())


# ---------------------------------------------------------------------------
# 4. store-backed admission: retired-page re-adoption + tconst O(1) hit
# ---------------------------------------------------------------------------


def test_retired_prefix_pages_readopted_without_reforward(lm_setup):
    """The satellite bugfix regression: after the only sharer of a
    prefix retires, its refcount-0 pages must retire INTO the store
    (pre-fix they left the content map at recycle) so a later admission
    of the same prefix re-adopts them — forwarding only the tail — and
    still streams exactly like a cold run."""
    cfg, api, params = lm_setup
    rng = np.random.RandomState(11)
    common = rng.randint(1, cfg.vocab_size, size=4 * PAGE).astype(np.int32)
    pa = np.concatenate([common, rng.randint(
        1, cfg.vocab_size, size=PAGE).astype(np.int32)])
    pb = np.concatenate([common, rng.randint(
        1, cfg.vocab_size, size=PAGE).astype(np.int32)])

    store = TierStore()
    sched = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                          slots=1, max_len=96, chunk_size=4,
                          prefix_sharing=True, prefill_chunk=PAGE,
                          tier_store=store)
    sa = sched.submit(Session(pa, max_new_tokens=4))
    sched.run()                                   # A done: pages recycled
    assert sched.spill_stats["pages_retired"] > 0
    assert not sched._prefix_map                  # nothing RESIDENT anymore
    assert len(sched.free_pages) == 40
    assert len(store) >= 4                        # ...but the content lives

    sb = sched.submit(Session(pb, max_new_tokens=4))
    sched.admit_pending()
    admit = sched.admit_stats[-1]
    assert sched.spill_stats["pages_readopted"] >= 4
    assert admit.forward_tokens < len(pb)         # tail-only: no re-forward
    sched.run()

    cold = SlotScheduler(build_decode(cfg, _spec("paged")), params,
                         slots=1, max_len=96, chunk_size=4,
                         prefill_chunk=PAGE)
    rb = cold.submit(Session(pb, max_new_tokens=4))
    cold.run()
    assert sb.tokens == rb.tokens, "re-adoption changed the stream"


def _jaxpr_primitives(jaxpr, acc):
    """All primitive names in a jaxpr, recursing into call/scan/cond
    sub-jaxprs carried in eqn params."""
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for val in eqn.params.values():
            for v in (val if isinstance(val, (tuple, list)) else (val,)):
                inner = getattr(v, "jaxpr", v)
                if hasattr(inner, "eqns"):
                    _jaxpr_primitives(inner, acc)
    return acc


def test_tconst_store_hit_readmission_zero_resync(tconst_setup):
    """The O(1) re-admission acceptance bar: admitting a prompt whose
    admission snapshot is in the store must (a) never call a prefill
    entry point, (b) report zero forwarded tokens, (c) run a restore
    program with no ``dot_general`` in it (count-asserted on the
    jaxpr), and (d) stream identically to the cold admission."""
    cfg, api, params = tconst_setup
    prompt = _prompts(cfg, 1, seed=13)[0]
    store = TierStore()

    def make():
        return SlotScheduler(build_decode(cfg, _spec("paged")), params,
                             slots=2, max_len=96, chunk_size=4,
                             prefix_sharing=True, tier_store=store)

    s1 = make()
    a = s1.submit(Session(prompt.copy(), max_new_tokens=8))
    s1.run()
    assert s1.admit_stats[-1].source == "cold"
    assert s1.spill_stats["admit_store_puts"] == 1

    s2 = make()

    def boom(*a, **k):                     # the O(N) paths must not run
        raise AssertionError("prefill ran on a store-hit admission")

    class NoPrefillDecode:                 # forwarding proxy: only the
        def __init__(self, inner):         # prefill entry points are mined
            self._inner = inner

        def __getattr__(self, name):
            return getattr(self._inner, name)

        prefill_into_slot = prefill_into_slot_chunked = staticmethod(boom)

    s2.decode = NoPrefillDecode(s2.decode)
    s2._prefill_slot = boom
    b = s2.submit(Session(prompt.copy(), max_new_tokens=8))
    s2.run()
    admit = s2.admit_stats[0]
    assert admit.source == "store" and admit.forward_tokens == 0
    assert s2.spill_stats["admit_store_hits"] == 1
    assert b.tokens == a.tokens

    # the restore program itself: one scatter, zero matmuls
    snap = jax.device_get(s2._snap(s2.state, np.int32(0)))
    closed = jax.make_jaxpr(
        lambda st, slot, sn: st.restore_slot(slot, sn))(
        s2.state, np.int32(0), snap)
    prims = _jaxpr_primitives(closed.jaxpr, set())
    assert "dot_general" not in prims and "conv_general_dilated" not in prims


def test_store_salt_separates_incompatible_schedulers(tconst_setup):
    """Admission snapshots must not cross schedulers whose max_len,
    layout, or prefill path differ — the salt keys them apart."""
    cfg, api, params = tconst_setup
    prompt = _prompts(cfg, 1, seed=17)[0]
    store = TierStore()
    s1 = SlotScheduler(build_decode(cfg, _spec("paged")), params, slots=1,
                       max_len=96, chunk_size=4, tier_store=store)
    sa = s1.submit(Session(prompt.copy(), max_new_tokens=6))
    s1.run()
    s2 = SlotScheduler(build_decode(cfg, _spec("paged")), params, slots=1,
                       max_len=64, chunk_size=4, tier_store=store)
    sb = s2.submit(Session(prompt.copy(), max_new_tokens=6))
    s2.run()
    assert s2.spill_stats["admit_store_hits"] == 0    # different max_len
    assert s2.admit_stats[0].source == "cold"
    assert sa.tokens == sb.tokens


def test_preemption_requires_store_and_validates_args(tconst_setup):
    cfg, api, params = tconst_setup
    dec = build_decode(cfg, _spec("paged"))
    with pytest.raises(ValueError, match="needs a tier_store"):
        SlotScheduler(dec, params, slots=1, max_len=96, chunk_size=4,
                      preempt_chunks=1)
    with pytest.raises(ValueError, match="must be positive"):
        SlotScheduler(dec, params, slots=1, max_len=96, chunk_size=4,
                      tier_store=TierStore(), preempt_chunks=0)

"""Quickstart: build the paper's TConstFormer, train it briefly on the
synthetic corpus, then stream tokens with the O(1) cache + periodic
resync schedule.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.serving.engine import Engine
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step
from repro.data.pipeline import DataConfig, batches


def main() -> None:
    # 1. the paper's architecture (reduced so this runs in seconds on CPU;
    #    drop `reduced` on real hardware for the full 41M configuration)
    cfg = reduced(get_config("tconst-41m"), dtype="float32", vocab_size=256)
    print(f"arch={cfg.name} mode={cfg.attention_mode} "
          f"blocks={cfg.tconst_blocks} W_oh={cfg.tconst.w_oh} "
          f"W_og={cfg.tconst.w_og} H={cfg.tconst.h}")

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    # 2. a short training run (sliding-window chunked forward, paper §5.1)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(api, opt_cfg, warmup_cosine(5, 60)),
                   donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=256, seq_len=32, batch_size=8)
    for i, b in enumerate(batches(dc, steps=60)):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"][:, :32])})
        if i % 20 == 0:
            print(f"step {i:3d} loss {float(m['loss']):.3f}")

    # 3. streaming generation: k-1 constant-time steps, then one resync
    eng = Engine(api, params, max_len=256, sample_temperature=0.8)
    prompt = {"tokens": jnp.asarray(next(iter(batches(
        dc, epoch=9, steps=1)))["tokens"][:2, :16])}
    out = eng.generate(prompt, 40, record_stats=True)
    kinds = [s.kind for s in eng.stats]
    print(f"generated {out.shape}; schedule: "
          f"{kinds.count('hit')} hits, {kinds.count('miss')} misses "
          f"(1 miss per W_og={cfg.tconst.w_og} tokens — paper §4)")
    print(f"KV cache bytes (constant in context length): "
          f"{eng.cache_bytes(2)}")


if __name__ == "__main__":
    main()

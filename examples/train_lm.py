"""End-to-end training driver: train a ~paper-scale model for a few
hundred steps on the synthetic corpus, with checkpointing and eval.

The default (--full) trains the paper's 41M-parameter TConstFormer
configuration for 200 steps — on CPU this takes a while; --reduced is the
seconds-scale variant.  Any assigned architecture id works via --arch
(e.g. --arch smollm-360m --mode tconst applies the paper's technique to
a llama-family model).

  PYTHONPATH=src python examples/train_lm.py --reduced --steps 100
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.data.pipeline import DataConfig, batches
from repro.models.api import build_model
from repro.training.checkpoint import save_train_state
from repro.training.optim import AdamWConfig, init_opt_state
from repro.training.schedules import warmup_cosine
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tconst-41m")
    ap.add_argument("--mode", default="",
                    help="override attention_mode (full|sliding|tconst|tlin)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    over = {"vocab_size": 512} if args.reduced else {}
    if args.mode:
        over["attention_mode"] = args.mode
    cfg = reduced(cfg, **over) if args.reduced else (
        cfg.replace(**over) if over else cfg)
    seq = args.seq or (cfg.tconst.w_og * 2
                       if cfg.attention_mode in ("tconst", "tlin") else 256)

    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"training {cfg.name} ({n/1e6:.1f}M params, "
          f"mode={cfg.attention_mode}) seq={seq}")

    opt_cfg = AdamWConfig(lr=args.lr)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(api, opt_cfg,
                                   warmup_cosine(args.steps // 10,
                                                 args.steps)),
                   donate_argnums=(0, 1))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                    batch_size=args.batch)
    t0 = time.time()
    for i, b in enumerate(batches(dc, steps=args.steps)):
        params, opt, m = step(params, opt,
                              {"tokens": jnp.asarray(b["tokens"][:, :seq])})
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"({args.batch*seq*(i+1)/(time.time()-t0):.0f} tok/s)")
    path = save_train_state(params, opt, args.steps, args.ckpt_dir)
    print(f"checkpoint -> {path}")


if __name__ == "__main__":
    main()

"""Streaming inference comparison — the paper's headline scenario.

Serves the SAME prompt through three matched-parameter variants
(Base / TLinFormer / TConstFormer) at growing context lengths and prints
per-step cache-hit latency, cache-miss latency, and KV-cache bytes:
the reduced-scale rerun of paper Fig. 8.

  PYTHONPATH=src python examples/streaming_serve.py --n-sweep 256,512,1024
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-sweep", default="256,512,1024")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    sweep = [int(x) for x in args.n_sweep.split(",")]

    print(f"{'variant':8s} {'N':>6s} {'hit ms':>9s} {'miss ms':>9s} "
          f"{'cache KiB':>10s}")
    for mode, label in [("full", "base"), ("tlin", "tlin"),
                        ("tconst", "tconst")]:
        cfg = reduced(get_config("tconst-41m"), dtype="float32",
                      attention_mode=mode)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        for n in sweep:
            eng = Engine(api, params, max_len=n + args.gen + 32)
            batch = {"tokens": jnp.ones((1, n), jnp.int32)}
            eng.generate(batch, args.gen, record_stats=True)  # warm-up
            eng.stats.clear()
            eng.generate(batch, args.gen, record_stats=True)
            hits = [s.seconds for s in eng.stats if s.kind == "hit"]
            misses = [s.seconds for s in eng.stats if s.kind == "miss"] or \
                [s.seconds for s in eng.stats if s.kind == "prefill"]
            print(f"{label:8s} {n:6d} {1e3*np.median(hits):9.2f} "
                  f"{1e3*np.median(misses):9.2f} "
                  f"{eng.cache_bytes(1)/1024:10.1f}")
    print("\nexpected (paper Fig 8): tconst hit-latency and cache size flat "
          "in N; base/tlin grow.")


if __name__ == "__main__":
    main()

"""Streaming inference comparison — the paper's headline scenario.

Serves the SAME prompt through three matched-parameter variants
(Base / TLinFormer / TConstFormer) at growing context lengths and prints
per-step cache-hit latency, cache-miss latency, and KV-cache bytes:
the reduced-scale rerun of paper Fig. 8.  The ``chunk tok/s`` column
uses the chunked decode path — one ``lax.scan`` dispatch per chunk with
the W_og resync fused on device (zero per-token host syncs).

  PYTHONPATH=src python examples/streaming_serve.py --n-sweep 256,512,1024

Minimal session-API usage (the streaming serving surface; see
``repro.launch.serve --sessions`` for the full continuous-batching demo)::

    from repro.serving import Session, SlotScheduler
    sched = SlotScheduler(api.decode, params, slots=4, max_len=2048)
    sched.submit(Session(prompt_ids, max_new_tokens=64,
                         on_token=lambda s, t: print(s.sid, t)))
    sched.run()     # tokens stream through the callback, per session
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, reduced
from repro.models.api import build_model
from repro.serving.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-sweep", default="256,512,1024")
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()
    sweep = [int(x) for x in args.n_sweep.split(",")]

    print(f"{'variant':8s} {'N':>6s} {'hit ms':>9s} {'miss ms':>9s} "
          f"{'cache KiB':>10s} {'chunk tok/s':>12s}")
    for mode, label in [("full", "base"), ("tlin", "tlin"),
                        ("tconst", "tconst")]:
        cfg = reduced(get_config("tconst-41m"), dtype="float32",
                      attention_mode=mode)
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        for n in sweep:
            eng = Engine(api, params, max_len=n + args.gen + 32)
            batch = {"tokens": jnp.ones((1, n), jnp.int32)}
            eng.generate(batch, args.gen, record_stats=True)  # warm-up
            eng.stats.clear()
            eng.generate(batch, args.gen, record_stats=True)
            hits = [s.seconds for s in eng.stats if s.kind == "hit"]
            misses = [s.seconds for s in eng.stats if s.kind == "miss"] or \
                [s.seconds for s in eng.stats if s.kind == "prefill"]
            # chunked path: one dispatch for the whole decode, no
            # per-token host syncs (resync fires via lax.cond on device;
            # prefill excluded — this is the O(1)-per-token quantity)
            chunk_tps = (args.gen - 1) / eng.time_chunked_decode(
                batch, args.gen)
            print(f"{label:8s} {n:6d} {1e3*np.median(hits):9.2f} "
                  f"{1e3*np.median(misses):9.2f} "
                  f"{eng.cache_bytes(1)/1024:10.1f} {chunk_tps:12.1f}")
    print("\nexpected (paper Fig 8): tconst hit-latency and cache size flat "
          "in N; base/tlin grow.")


if __name__ == "__main__":
    main()
